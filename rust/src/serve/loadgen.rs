//! Load generators for `qtx serve` — the measurement half of the serving
//! acceptance loop (`qtx loadgen`, `bench_serve`).
//!
//! Two client shapes:
//!
//! * **Closed loop** (default): N client threads, each with one keep-alive
//!   connection, firing the next request as soon as the previous response
//!   lands. Simple and self-pacing, but it can never offer more load than
//!   the server returns — queueing pathologies (convoys behind the fixed
//!   batcher's flush clock) are invisible to it.
//! * **Open loop** (`open_rate_rps`): request *arrival times* are drawn
//!   from a Poisson process at the offered rate, independent of server
//!   progress, and a pool of sender threads fires each request at its
//!   scheduled instant. Latency is measured from the scheduled arrival —
//!   not the actual send — so client-side lag counts against the result
//!   (no coordinated omission). This is the client that exposes convoy
//!   effects and makes batching policies comparable.
//!
//! Both shapes parse each `200` body and aggregate the server-reported
//! `queue_ms` (time queued before the batch launched) alongside wall-clock
//! latency percentiles.
//!
//! Failures never abort a run: connection refusals, resets, timeouts and
//! non-200 statuses are counted per cause (`errors_by_cause`) and the
//! schedule continues — the shape a router fault drill needs, where
//! replicas are killed mid-run on purpose and the interesting result is
//! exactly how many requests were lost and why.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::serve::protocol::{GenerateRequest, GenerateResponse, ScoreRequest, ScoreResponse};
use crate::serve::server::Client;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Generation-mode parameters (`qtx loadgen --generate`): drive
/// `POST /v1/generate` instead of `/v1/score`, in either loop shape.
#[derive(Debug, Clone, Copy)]
pub struct GenLoad {
    /// New tokens per session (each request pins a slot this long).
    pub max_new_tokens: usize,
    /// Exact prompt length; 0 = random in `[1, seq_len - max_new_tokens]`.
    pub prompt_len: usize,
    /// Request `"stream": true` and consume the chunked token events
    /// (latency still measured to the terminal `done` event).
    pub stream: bool,
    /// Sampling temperature forwarded to the server; 0.0 = greedy.
    pub temperature: f32,
    /// Top-k forwarded to the server; 0 disables.
    pub top_k: usize,
    /// Top-p forwarded to the server; 1.0 disables.
    pub top_p: f32,
}

impl GenLoad {
    /// Greedy, non-streaming defaults (the PR-5 shape).
    pub fn greedy(max_new_tokens: usize, prompt_len: usize) -> GenLoad {
        GenLoad {
            max_new_tokens,
            prompt_len,
            stream: false,
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Target `host:port`.
    pub addr: String,
    /// Closed loop: concurrent clients. Open loop: sender-pool size (must
    /// cover the offered rate × typical latency, or lag is reported).
    pub clients: usize,
    /// Requests per client/sender; total = `clients × requests_per_client`.
    pub requests_per_client: usize,
    /// Token-id range for synthetic sequences; 0 = ask /healthz for the
    /// model's vocab (out-of-vocab ids are rejected with 400).
    pub vocab: usize,
    /// Max sequence length to generate; 0 = ask /healthz for the model's
    /// seq_len and use it.
    pub seq_len: usize,
    pub seed: u64,
    pub timeout: Duration,
    /// `Some(rate)`: open-loop mode, Poisson arrivals at `rate` req/s
    /// across the whole pool. `None`: closed loop.
    pub open_rate_rps: Option<f64>,
    /// `Some`: drive `/v1/generate` (decode sessions) instead of
    /// `/v1/score`, in either loop shape.
    pub gen: Option<GenLoad>,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:8787".into(),
            clients: 4,
            requests_per_client: 64,
            vocab: 0,
            seq_len: 0,
            seed: 0,
            timeout: Duration::from_secs(30),
            open_rate_rps: None,
            gen: None,
        }
    }
}

/// A block of held keep-alive connections — the fixture behind
/// `qtx loadgen --connections N` and the 1k-connection smoke. All `n`
/// sockets are opened up front and sit mostly idle (the event-loop
/// server keeps them at zero thread cost); [`ConnectionHold::trickle`]
/// pushes a request through a rotating member to prove held sockets stay
/// serviceable. Dropping the hold closes every socket.
pub struct ConnectionHold {
    conns: Vec<Client>,
}

impl ConnectionHold {
    /// Open `n` keep-alive connections to `addr`. Fails on the first
    /// connect error — a partial hold would silently weaken the test.
    pub fn open(addr: &str, n: usize, timeout: Duration) -> Result<ConnectionHold> {
        let mut conns = Vec::with_capacity(n);
        for i in 0..n {
            conns.push(
                Client::connect(addr, timeout)
                    .with_context(|| format!("opening held connection {i} of {n}"))?,
            );
        }
        Ok(ConnectionHold { conns })
    }

    pub fn len(&self) -> usize {
        self.conns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.conns.is_empty()
    }

    /// Send one request through held connection `i % n` (a trickle over
    /// otherwise-idle sockets) and return the response status.
    pub fn trickle(
        &mut self,
        i: usize,
        method: &str,
        path: &str,
        body: Option<&Json>,
    ) -> Result<u16> {
        anyhow::ensure!(!self.conns.is_empty(), "no held connections");
        let k = i % self.conns.len();
        let (status, _body) = self.conns[k]
            .request(method, path, body)
            .with_context(|| format!("trickle request over held connection {k}"))?;
        Ok(status)
    }
}

/// Aggregated results from one loadgen run.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    /// "closed" or "open".
    pub mode: &'static str,
    /// Open loop: the configured arrival rate. Closed loop: 0.
    pub offered_rps: f64,
    pub clients: usize,
    pub sent: u64,
    pub ok: u64,
    pub errors: u64,
    /// `errors` broken down by cause (`refused`, `reset`, `timeout`,
    /// `http_503`, ...), sorted by cause name. Empty when `errors == 0`.
    /// Resets and refusals are *counted* here, never aborted on — a
    /// router drill kills replicas mid-run on purpose.
    pub errors_by_cause: Vec<(String, u64)>,
    pub elapsed_s: f64,
    /// Successful requests per second, wall-clock.
    pub throughput_rps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    /// Server-reported time queued before batch launch (from `queue_ms` in
    /// each 200 response) — the number batching policies compete on.
    pub queue_p50_ms: f64,
    pub queue_p95_ms: f64,
    pub queue_p99_ms: f64,
    /// Open loop: p95 of how late senders fired after the scheduled arrival
    /// (pool saturation indicator; latency already includes this). 0 when
    /// closed loop.
    pub lag_p95_ms: f64,
    /// Generate mode: total tokens received across all 200 responses
    /// (0 in score mode).
    pub gen_tokens_total: u64,
    /// Generate mode: tokens per second, wall-clock (0 in score mode).
    pub gen_tokens_per_s: f64,
}

impl LoadgenReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mode", Json::Str(self.mode.into())),
            ("offered_rps", Json::Num(self.offered_rps)),
            ("clients", Json::Num(self.clients as f64)),
            ("sent", Json::Num(self.sent as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("errors", Json::Num(self.errors as f64)),
            (
                "errors_by_cause",
                Json::obj(
                    self.errors_by_cause
                        .iter()
                        .map(|(k, v)| (k.as_str(), Json::Num(*v as f64)))
                        .collect(),
                ),
            ),
            ("elapsed_s", Json::Num(self.elapsed_s)),
            ("throughput_rps", Json::Num(self.throughput_rps)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p95_ms", Json::Num(self.p95_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("mean_ms", Json::Num(self.mean_ms)),
            ("queue_p50_ms", Json::Num(self.queue_p50_ms)),
            ("queue_p95_ms", Json::Num(self.queue_p95_ms)),
            ("queue_p99_ms", Json::Num(self.queue_p99_ms)),
            ("lag_p95_ms", Json::Num(self.lag_p95_ms)),
            ("gen_tokens_total", Json::Num(self.gen_tokens_total as f64)),
            ("gen_tokens_per_s", Json::Num(self.gen_tokens_per_s)),
        ])
    }
}

/// Probed `/healthz` facts.
#[derive(Debug, Clone, Copy)]
pub struct ServerLimits {
    pub seq_len: usize,
    pub max_batch: usize,
    pub vocab: usize,
}

/// Probe `/healthz` for the model's limits. A warming-up server answers
/// 503 (`status: "unavailable"`) but the document already carries the
/// limits, so the probe accepts it — requests sent before the engines are
/// ready simply queue, exactly the pre-healthz-503 behavior.
pub fn probe(addr: &str, timeout: Duration) -> Result<ServerLimits> {
    let mut c = Client::connect(addr, timeout)?;
    let (status, body) = c.request("GET", "/healthz", None)?;
    if status != 200 && status != 503 {
        anyhow::bail!("GET /healthz: status {status}: {body}");
    }
    let h = crate::util::json::Json::parse(&body)
        .map_err(|e| anyhow::anyhow!("GET /healthz: bad json: {e}"))?;
    let get = |k: &str| -> Result<usize> {
        h.req(k)?.as_usize().with_context(|| format!("healthz {k} not an integer"))
    };
    Ok(ServerLimits {
        seq_len: get("seq_len")?,
        max_batch: get("max_batch")?,
        vocab: get("vocab")?,
    })
}

/// One successful request's measurements.
#[derive(Debug, Clone, Copy)]
struct Sample {
    lat_ms: f32,
    queue_ms: f32,
    /// Generate mode: tokens received (0 in score mode).
    tokens: u32,
}

/// Deterministic synthetic scoring request for schedule position `i`.
fn synth_request(seed: u64, label: &str, i: usize, seq_len: usize, vocab: u32) -> ScoreRequest {
    let mut rng = Rng::new(seed).fork(&format!("{label}-{i}"));
    let len = 2 + rng.below(seq_len as u32 - 1) as usize;
    ScoreRequest {
        id: Some(format!("{label}-{i}")),
        tokens: (0..len).map(|_| rng.below(vocab) as i32).collect(),
        targets: None,
    }
}

/// Deterministic synthetic generation request for schedule position `i`:
/// prompt + continuation always fit the model's KV-cache capacity.
fn synth_generate(
    seed: u64,
    label: &str,
    i: usize,
    seq_len: usize,
    vocab: u32,
    gen: GenLoad,
) -> GenerateRequest {
    let mut rng = Rng::new(seed).fork(&format!("{label}-{i}"));
    let max_prompt = seq_len.saturating_sub(gen.max_new_tokens).max(1);
    let len = if gen.prompt_len > 0 {
        gen.prompt_len.min(max_prompt)
    } else {
        1 + rng.below(max_prompt as u32) as usize
    };
    GenerateRequest {
        id: Some(format!("{label}-{i}")),
        tokens: (0..len).map(|_| rng.below(vocab) as i32).collect(),
        max_new_tokens: gen.max_new_tokens,
        stream: gen.stream,
        temperature: gen.temperature,
        top_k: gen.top_k,
        top_p: gen.top_p,
        // Pin an explicit per-request seed when sampling so reruns of the
        // same schedule generate identical continuations; greedy requests
        // omit it and stay byte-identical to the pre-sampling wire shape.
        seed: (gen.temperature > 0.0).then(|| u64::from(rng.next_u32()) | 1),
    }
}

/// Path + request body for schedule position `i` under the configured
/// mode.
fn synth_body(
    seed: u64,
    label: &str,
    i: usize,
    seq_len: usize,
    vocab: u32,
    gen: Option<GenLoad>,
) -> (&'static str, Json) {
    match gen {
        Some(g) => ("/v1/generate", synth_generate(seed, label, i, seq_len, vocab, g).to_json()),
        None => ("/v1/score", synth_request(seed, label, i, seq_len, vocab).to_json()),
    }
}

/// Consume one streamed generation on an established connection: count
/// `token` events, then build the sample from the terminal `done` event
/// (which carries the full response body, `queue_ms` included). Returns
/// `None` on any error or early termination — the caller must then drop
/// the connection, since mid-stream chunk state cannot be resynced.
fn stream_one(c: &mut Client, path: &str, body: &Json, sent: Instant) -> Option<Sample> {
    let (status, _head) = c.request_streaming("POST", path, Some(body)).ok()?;
    if status != 200 {
        return None;
    }
    let mut streamed = 0u32;
    loop {
        let chunk = c.next_chunk().ok()??;
        let ev = Json::parse(chunk.trim()).ok()?;
        match ev.get("event").and_then(Json::as_str) {
            Some("token") => streamed += 1,
            Some("done") => {
                // Drain the terminal zero-chunk so the keep-alive
                // connection is aligned for the next request.
                if c.next_chunk().ok()?.is_some() {
                    return None;
                }
                let resp = GenerateResponse::parse(&chunk).ok()?;
                let lat_ms = sent.elapsed().as_secs_f64() as f32 * 1000.0;
                if resp.tokens.len() as u32 != streamed {
                    return None;
                }
                return Some(Sample { lat_ms, queue_ms: resp.queue_ms as f32, tokens: streamed });
            }
            _ => return None, // error event (or garbage): count as a failure
        }
    }
}

/// Map a transport error onto the cause key it is counted under in
/// `errors_by_cause`. Walks the anyhow chain for the underlying
/// `io::Error`; a clean server-side close surfaces as a contextual
/// message with no io error underneath, which is still a reset as far
/// as the client is concerned.
fn classify_err(e: &anyhow::Error) -> &'static str {
    use std::io::ErrorKind;
    if let Some(io) = e.chain().find_map(|c| c.downcast_ref::<std::io::Error>()) {
        return match io.kind() {
            ErrorKind::ConnectionRefused => "refused",
            ErrorKind::ConnectionReset
            | ErrorKind::ConnectionAborted
            | ErrorKind::BrokenPipe
            | ErrorKind::UnexpectedEof => "reset",
            ErrorKind::TimedOut | ErrorKind::WouldBlock => "timeout",
            _ => "io",
        };
    }
    if format!("{e:#}").contains("server closed connection") {
        return "reset";
    }
    "protocol"
}

/// Send one request on `client`, reconnecting on transport errors.
/// Returns the sample on 200, or the failure cause (counted — never
/// aborted on — by the caller; a router drill kills replicas mid-run on
/// purpose, so resets and refusals are data, not fatal errors).
/// The response type follows from the path, so the two cannot disagree.
fn send_one(
    client: &mut Option<Client>,
    addr: &str,
    timeout: Duration,
    path: &str,
    body: &Json,
    stream: bool,
    sent: Instant,
) -> Result<Sample, &'static str> {
    if client.is_none() {
        match Client::connect(addr, timeout) {
            Ok(c) => *client = Some(c),
            Err(e) => return Err(classify_err(&e)),
        }
    }
    let c = client.as_mut().expect("connected above");
    if stream && path == "/v1/generate" {
        return match stream_one(c, path, body, sent) {
            Some(s) => Ok(s),
            None => {
                // Chunked state may be desynced; force a redial next time.
                *client = None;
                Err("stream")
            }
        };
    }
    match c.request("POST", path, Some(body)) {
        Ok((200, body)) => {
            // An unparseable 200 body is an error, not a 0 ms queue wait —
            // silent zeros would skew the very percentiles the batching
            // policies are compared on.
            let lat_ms = sent.elapsed().as_secs_f64() as f32 * 1000.0;
            if path == "/v1/generate" {
                let resp = GenerateResponse::parse(&body).map_err(|_| "parse")?;
                Ok(Sample {
                    lat_ms,
                    queue_ms: resp.queue_ms as f32,
                    tokens: resp.tokens.len() as u32,
                })
            } else {
                let resp = ScoreResponse::parse(&body).map_err(|_| "parse")?;
                Ok(Sample { lat_ms, queue_ms: resp.queue_ms as f32, tokens: 0 })
            }
        }
        // 503s get their own bucket: under a router they are the shed
        // contract (deliberate), unlike other 5xx which are failures.
        Ok((503, _body)) => Err("http_503"),
        Ok((status, _body)) if status < 500 => Err("http_4xx"),
        Ok((_status, _body)) => Err("http_5xx"),
        Err(e) => {
            // Transport error: drop the connection so the next call redials.
            *client = None;
            Err(classify_err(&e))
        }
    }
}

fn resolve_limits(cfg: &LoadgenConfig) -> Result<(usize, u32)> {
    let (seq_len, vocab) = if cfg.seq_len > 0 && cfg.vocab > 0 {
        (cfg.seq_len, cfg.vocab)
    } else {
        let limits = probe(&cfg.addr, cfg.timeout)
            .context("probing server (pass --seq-len and --vocab to skip the probe)")?;
        (
            if cfg.seq_len > 0 { cfg.seq_len } else { limits.seq_len },
            if cfg.vocab > 0 { cfg.vocab } else { limits.vocab },
        )
    };
    let seq_len = seq_len.max(2);
    if let Some(g) = cfg.gen {
        anyhow::ensure!(
            g.max_new_tokens >= 1 && g.max_new_tokens < seq_len,
            "--max-new-tokens {} must be in [1, seq_len {} - 1] (prompt + continuation \
             share the KV cache)",
            g.max_new_tokens,
            seq_len
        );
    }
    Ok((seq_len, vocab.clamp(2, i32::MAX as usize) as u32))
}

/// Run the configured loop; blocks until every request resolved.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    match cfg.open_rate_rps {
        Some(rate) => run_open(cfg, rate),
        None => run_closed(cfg),
    }
}

/// Per-cause error tally (cause key → count). Threads each keep their
/// own and the driver merges at join — no shared mutex on the hot path.
type CauseCounts = BTreeMap<&'static str, u64>;

fn merge_causes(into: &mut CauseCounts, from: CauseCounts) {
    for (k, v) in from {
        *into.entry(k).or_insert(0) += v;
    }
}

fn run_closed(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    let (seq_len, vocab) = resolve_limits(cfg)?;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for client_id in 0..cfg.clients.max(1) {
        let addr = cfg.addr.clone();
        let timeout = cfg.timeout;
        let n = cfg.requests_per_client;
        let seed = cfg.seed;
        let gen = cfg.gen;
        handles.push(std::thread::spawn(move || -> (Vec<Sample>, CauseCounts) {
            let mut samples = Vec::with_capacity(n);
            let mut causes = CauseCounts::new();
            // `send_one` dials lazily and redials after transport errors,
            // so a replica dying (or not yet listening) costs exactly the
            // requests that failed — the rest of the schedule still runs.
            let mut client: Option<Client> = None;
            let label = format!("c{client_id}");
            let stream = gen.map_or(false, |g| g.stream);
            for i in 0..n {
                let (path, body) = synth_body(seed, &label, i, seq_len, vocab, gen);
                match send_one(&mut client, &addr, timeout, path, &body, stream, Instant::now()) {
                    Ok(s) => samples.push(s),
                    Err(cause) => *causes.entry(cause).or_insert(0) += 1,
                }
            }
            (samples, causes)
        }));
    }
    let mut samples: Vec<Sample> = Vec::new();
    let mut causes = CauseCounts::new();
    for h in handles {
        let (s, c) = h.join().expect("loadgen client panicked");
        samples.extend(s);
        merge_causes(&mut causes, c);
    }
    let elapsed_s = t0.elapsed().as_secs_f64();
    Ok(build_report("closed", 0.0, cfg.clients.max(1), samples, Vec::new(), causes, elapsed_s))
}

/// Cumulative Poisson arrival offsets: `n` exponential inter-arrivals at
/// `rate` req/s, deterministic per seed. (`1.0 - f64()` keeps the ln
/// argument in (0, 1] — `Rng::f64` is [0, 1).)
fn poisson_schedule(seed: u64, rate: f64, n: usize) -> Vec<Duration> {
    let mut sched = Vec::with_capacity(n);
    let mut rng = Rng::new(seed).fork("arrivals");
    let mut t = 0.0f64;
    for _ in 0..n {
        t += -(1.0 - rng.f64()).ln() / rate;
        sched.push(Duration::from_secs_f64(t));
    }
    sched
}

fn run_open(cfg: &LoadgenConfig, rate: f64) -> Result<LoadgenReport> {
    anyhow::ensure!(rate > 0.0, "open-loop rate must be > 0 req/s");
    let (seq_len, vocab) = resolve_limits(cfg)?;
    let clients = cfg.clients.max(1);
    let total = clients * cfg.requests_per_client;
    let sched = Arc::new(poisson_schedule(cfg.seed, rate, total));

    let next = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for _ in 0..clients {
        let addr = cfg.addr.clone();
        let timeout = cfg.timeout;
        let seed = cfg.seed;
        let gen = cfg.gen;
        let next = next.clone();
        let sched = sched.clone();
        handles.push(std::thread::spawn(move || -> (Vec<Sample>, Vec<f32>, CauseCounts) {
            let mut samples = Vec::new();
            let mut lags = Vec::new();
            let mut causes = CauseCounts::new();
            let mut client: Option<Client> = Client::connect(&addr, timeout).ok();
            loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= sched.len() {
                    break;
                }
                let due = t0 + sched[i];
                let now = Instant::now();
                if now < due {
                    std::thread::sleep(due - now);
                }
                lags.push(due.elapsed().as_secs_f64() as f32 * 1000.0);
                let (path, body) = synth_body(seed, "o", i, seq_len, vocab, gen);
                // Latency clock starts at the *scheduled* arrival: sender
                // lag and server time both count (open-loop semantics).
                let stream = gen.map_or(false, |g| g.stream);
                match send_one(&mut client, &addr, timeout, path, &body, stream, due) {
                    Ok(s) => samples.push(s),
                    Err(cause) => *causes.entry(cause).or_insert(0) += 1,
                }
            }
            (samples, lags, causes)
        }));
    }
    let mut samples: Vec<Sample> = Vec::new();
    let mut lags: Vec<f32> = Vec::new();
    let mut causes = CauseCounts::new();
    for h in handles {
        let (s, l, c) = h.join().expect("loadgen sender panicked");
        samples.extend(s);
        lags.extend(l);
        merge_causes(&mut causes, c);
    }
    let elapsed_s = t0.elapsed().as_secs_f64();
    Ok(build_report("open", rate, clients, samples, lags, causes, elapsed_s))
}

fn pcts(values: &mut [f32]) -> (f64, f64, f64) {
    if values.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    values.sort_by(f32::total_cmp);
    (
        crate::util::stats::percentile_sorted(values, 50.0) as f64,
        crate::util::stats::percentile_sorted(values, 95.0) as f64,
        crate::util::stats::percentile_sorted(values, 99.0) as f64,
    )
}

fn build_report(
    mode: &'static str,
    offered_rps: f64,
    clients: usize,
    samples: Vec<Sample>,
    mut lags: Vec<f32>,
    causes: CauseCounts,
    elapsed_s: f64,
) -> LoadgenReport {
    let ok = samples.len() as u64;
    let errors: u64 = causes.values().sum();
    let errors_by_cause: Vec<(String, u64)> =
        causes.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
    let mut lat: Vec<f32> = samples.iter().map(|s| s.lat_ms).collect();
    let mut queue: Vec<f32> = samples.iter().map(|s| s.queue_ms).collect();
    let mean_ms = if lat.is_empty() { 0.0 } else { crate::util::stats::mean(&lat) };
    let (p50, p95, p99) = pcts(&mut lat);
    let (q50, q95, q99) = pcts(&mut queue);
    let (_, lag95, _) = pcts(&mut lags);
    let gen_tokens_total: u64 = samples.iter().map(|s| u64::from(s.tokens)).sum();
    LoadgenReport {
        mode,
        offered_rps,
        clients,
        sent: ok + errors,
        ok,
        errors,
        errors_by_cause,
        elapsed_s,
        throughput_rps: if elapsed_s > 0.0 { ok as f64 / elapsed_s } else { 0.0 },
        p50_ms: p50,
        p95_ms: p95,
        p99_ms: p99,
        mean_ms,
        queue_p50_ms: q50,
        queue_p95_ms: q95,
        queue_p99_ms: q99,
        lag_p95_ms: lag95,
        gen_tokens_total,
        gen_tokens_per_s: if elapsed_s > 0.0 { gen_tokens_total as f64 / elapsed_s } else { 0.0 },
    }
}

/// Render the human-readable report table.
pub fn render_report(r: &LoadgenReport) -> String {
    let mut out = crate::metrics::table::render(
        &[
            "mode", "clients", "ok", "errors", "req/s", "p50 ms", "p95 ms", "p99 ms", "q p95 ms",
        ],
        &[vec![
            if r.mode == "open" {
                format!("open@{:.0}rps", r.offered_rps)
            } else {
                r.mode.to_string()
            },
            r.clients.to_string(),
            r.ok.to_string(),
            r.errors.to_string(),
            format!("{:.1}", r.throughput_rps),
            format!("{:.2}", r.p50_ms),
            format!("{:.2}", r.p95_ms),
            format!("{:.2}", r.p99_ms),
            format!("{:.2}", r.queue_p95_ms),
        ]],
    );
    if r.gen_tokens_total > 0 {
        out.push_str(&format!(
            "\ndecode: {} tokens generated, {:.1} tok/s",
            r.gen_tokens_total, r.gen_tokens_per_s
        ));
    }
    if r.errors > 0 {
        let causes: Vec<String> =
            r.errors_by_cause.iter().map(|(k, v)| format!("{k}={v}")).collect();
        out.push_str(&format!("\nerrors by cause: {}", causes.join(" ")));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_shape() {
        let r = LoadgenReport {
            mode: "open",
            offered_rps: 500.0,
            clients: 2,
            sent: 10,
            ok: 9,
            errors: 1,
            errors_by_cause: vec![("reset".to_string(), 1)],
            elapsed_s: 1.5,
            throughput_rps: 6.0,
            p50_ms: 1.0,
            p95_ms: 2.0,
            p99_ms: 3.0,
            mean_ms: 1.2,
            queue_p50_ms: 0.4,
            queue_p95_ms: 0.9,
            queue_p99_ms: 1.1,
            lag_p95_ms: 0.1,
            gen_tokens_total: 72,
            gen_tokens_per_s: 48.0,
        };
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.req("ok").unwrap().as_usize(), Some(9));
        assert_eq!(j.req("mode").unwrap().as_str(), Some("open"));
        assert_eq!(j.req("offered_rps").unwrap().as_usize(), Some(500));
        assert_eq!(j.req("gen_tokens_total").unwrap().as_usize(), Some(72));
        assert!(j.req("queue_p95_ms").unwrap().as_f64().unwrap() > 0.0);
        let by_cause = j.req("errors_by_cause").unwrap();
        assert_eq!(by_cause.req("reset").unwrap().as_usize(), Some(1));
        assert!(render_report(&r).contains("req/s"));
        assert!(render_report(&r).contains("open@500rps"));
        assert!(render_report(&r).contains("48.0 tok/s"));
        assert!(render_report(&r).contains("errors by cause: reset=1"));
    }

    #[test]
    fn error_causes_merge_and_total_into_the_report() {
        let mut a = CauseCounts::new();
        a.insert("reset", 2);
        a.insert("refused", 1);
        let mut b = CauseCounts::new();
        b.insert("reset", 1);
        b.insert("http_503", 4);
        merge_causes(&mut a, b);
        let r = build_report("closed", 0.0, 1, Vec::new(), Vec::new(), a, 1.0);
        assert_eq!(r.errors, 8);
        assert_eq!(r.sent, 8);
        // BTreeMap keeps causes sorted, so the report order is stable.
        let names: Vec<&str> = r.errors_by_cause.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(names, vec!["http_503", "refused", "reset"]);
        assert_eq!(r.errors_by_cause[2], ("reset".to_string(), 3));
    }

    #[test]
    fn classify_err_buckets_io_kinds_and_message_shapes() {
        use std::io::{Error, ErrorKind};
        let io = |k: ErrorKind| anyhow::Error::from(Error::new(k, "boom"));
        assert_eq!(classify_err(&io(ErrorKind::ConnectionRefused)), "refused");
        assert_eq!(classify_err(&io(ErrorKind::ConnectionReset)), "reset");
        assert_eq!(classify_err(&io(ErrorKind::BrokenPipe)), "reset");
        assert_eq!(classify_err(&io(ErrorKind::UnexpectedEof)), "reset");
        assert_eq!(classify_err(&io(ErrorKind::TimedOut)), "timeout");
        assert_eq!(classify_err(&io(ErrorKind::PermissionDenied)), "io");
        // Context-wrapped io errors still classify by the inner kind.
        let wrapped = io(ErrorKind::ConnectionReset).context("reading chunk size");
        assert_eq!(classify_err(&wrapped), "reset");
        // A clean server-side close has no io error in the chain.
        assert_eq!(classify_err(&anyhow::anyhow!("server closed connection")), "reset");
        assert_eq!(classify_err(&anyhow::anyhow!("bad status line")), "protocol");
    }

    #[test]
    fn synth_generate_fits_cache_and_is_deterministic() {
        let g = GenLoad::greedy(8, 0);
        for i in 0..20 {
            let r = synth_generate(7, "o", i, 32, 100, g);
            assert!(!r.tokens.is_empty());
            assert!(r.tokens.len() + r.max_new_tokens <= 32, "{}", r.tokens.len());
            assert_eq!(r, synth_generate(7, "o", i, 32, 100, g));
            // Greedy requests never pin a seed (wire shape stays minimal).
            assert_eq!(r.seed, None);
            assert!(!r.stream);
        }
        // Exact prompt length is honored (and clamped to fit the cache).
        let fixed = synth_generate(7, "o", 0, 32, 100, GenLoad::greedy(8, 12));
        assert_eq!(fixed.tokens.len(), 12);
        let clamped = synth_generate(7, "o", 0, 32, 100, GenLoad::greedy(30, 12));
        assert_eq!(clamped.tokens.len(), 2);
        // Sampled requests pin a deterministic per-index seed.
        let sampled = GenLoad { temperature: 0.8, top_k: 5, ..GenLoad::greedy(8, 0) };
        let a = synth_generate(7, "o", 3, 32, 100, sampled);
        let b = synth_generate(7, "o", 3, 32, 100, sampled);
        assert_eq!(a.seed, b.seed);
        assert!(a.seed.is_some());
        assert_eq!(a.temperature, 0.8);
        assert_eq!(a.top_k, 5);
    }

    #[test]
    fn poisson_schedule_is_deterministic_and_rate_accurate() {
        let a = poisson_schedule(7, 1000.0, 4000);
        let b = poisson_schedule(7, 1000.0, 4000);
        assert_eq!(a, b, "same seed, same schedule");
        // 4000 arrivals at 1000 req/s take ~4s (CLT: well within 10%).
        let span = a.last().unwrap().as_secs_f64();
        assert!((3.6..4.4).contains(&span), "span {span}");
        // Cumulative times never go backwards (ties possible only at the
        // nanosecond rounding of Duration, so don't require strictness).
        assert!(a.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn synth_requests_are_deterministic_per_index() {
        let a = synth_request(7, "o", 3, 32, 100);
        let b = synth_request(7, "o", 3, 32, 100);
        let c = synth_request(7, "o", 4, 32, 100);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.tokens.len() >= 2 && a.tokens.len() <= 32);
        assert!(a.tokens.iter().all(|&t| t >= 0 && t < 100));
    }
}
