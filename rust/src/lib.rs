//! # qtx — Quantizable Transformers in Rust + JAX + Pallas
//!
//! Reproduction of *"Quantizable Transformers: Removing Outliers by Helping
//! Attention Heads Do Nothing"* (Bondarenko, Nagel, Blankevoort — NeurIPS
//! 2023) as a three-layer system:
//!
//! * **L1/L2** (build time, python): Pallas attention/LayerNorm/fake-quant
//!   kernels inside a JAX transformer, AOT-lowered to HLO text per model
//!   config (`make artifacts`).
//! * **L3** (this crate): the coordinator — synthetic data substrates,
//!   training orchestration, post-training quantization (calibration, range
//!   estimation, weight fake-quant), outlier analysis, and the benchmark
//!   harness that regenerates every table and figure of the paper.
//!
//! Python never runs on the request path: the `qtx` binary only loads
//! `artifacts/*.hlo.txt` through the PJRT C API (`xla` crate).
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`util`] — hand-rolled substrate (JSON, PRNG, tensors, stats, CLI,
//!   property-testing, checkpoint IO); the offline vendor set has no serde/
//!   rand/clap/criterion/proptest, so these are first-class modules here.
//! * [`runtime`] — PJRT client, artifact manifests, named-IO programs.
//! * [`data`] — synthetic corpus / vision substrates (MLM, CLM, patches).
//! * [`quant`] — uniform affine quantization, range estimators, weight PTQ.
//! * [`metrics`] — perplexity/accuracy/kurtosis/inf-norm + table formatting.
//! * [`analysis`] — outlier localization and attention-pattern dumps.
//! * [`coordinator`] — trainer, evaluator, calibrator, experiment runner.
//! * [`serve`] — the request path: dynamic-batching INT8 inference server
//!   (`qtx serve`) + closed-loop load generator (`qtx loadgen`).
//! * [`infer`] — native INT8 CPU backend: real `i8` weights and integer
//!   GEMMs behind the same `ScoreEngine` trait
//!   (`qtx serve --engine native-int8`).
//!
//! New here? Start with the repo-root `README.md`, then
//! `docs/ARCHITECTURE.md` for the subsystem map and `docs/API.md` for the
//! HTTP contract.

pub mod analysis;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod infer;
pub mod metrics;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
