//! Learning-rate schedules (§C.1-C.3). The schedule runs in rust and feeds
//! the per-step `lr` scalar into the AOT train_step, so schedule ablations
//! never require re-lowering.

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    /// Linear warmup to `lr_max`, then linear decay to zero at `total`
    /// (BERT §C.1, OPT §C.2).
    LinearWarmupDecay,
    /// Linear warmup then cosine decay to `min_frac * lr_max` (ViT §C.3).
    WarmupCosine { min_frac: f64 },
    /// Constant after warmup (fine-tuning, §B.6).
    WarmupConstant,
}

impl Schedule {
    pub fn for_family(family: &str) -> Schedule {
        match family {
            "vit" => Schedule::WarmupCosine { min_frac: 0.01 },
            _ => Schedule::LinearWarmupDecay,
        }
    }

    /// LR for 0-based step index.
    pub fn lr(&self, step: usize, total: usize, warmup: usize, lr_max: f64) -> f64 {
        let s = step as f64;
        let w = warmup.max(1) as f64;
        if step < warmup {
            return lr_max * (s + 1.0) / w;
        }
        let t = total.max(warmup + 1) as f64;
        match self {
            Schedule::LinearWarmupDecay => {
                let frac = (t - s) / (t - w);
                lr_max * frac.max(0.0)
            }
            Schedule::WarmupCosine { min_frac } => {
                let prog = ((s - w) / (t - w)).clamp(0.0, 1.0);
                let cos = 0.5 * (1.0 + (std::f64::consts::PI * prog).cos());
                lr_max * (min_frac + (1.0 - min_frac) * cos)
            }
            Schedule::WarmupConstant => lr_max,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn warmup_ramps() {
        let s = Schedule::LinearWarmupDecay;
        let lr0 = s.lr(0, 100, 10, 1.0);
        let lr9 = s.lr(9, 100, 10, 1.0);
        assert!(lr0 < lr9);
        assert!((lr9 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn linear_decays_to_zero() {
        let s = Schedule::LinearWarmupDecay;
        assert!((s.lr(100, 100, 10, 1.0)).abs() < 1e-9);
        assert!((s.lr(55, 100, 10, 1.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn cosine_floors_at_min_frac() {
        let s = Schedule::WarmupCosine { min_frac: 0.01 };
        assert!((s.lr(1000, 1000, 10, 1.0) - 0.01).abs() < 1e-9);
        assert!(s.lr(500, 1000, 10, 1.0) > 0.3);
    }

    #[test]
    fn constant_after_warmup() {
        let s = Schedule::WarmupConstant;
        assert_eq!(s.lr(50, 100, 10, 2.0), 2.0);
        assert!(s.lr(5, 100, 10, 2.0) < 2.0);
    }

    #[test]
    fn prop_monotone_decay_after_warmup() {
        check(
            "lr_monotone_after_warmup",
            |rng| {
                let total = 50 + rng.below(500) as usize;
                let warmup = rng.below(total as u32 / 2) as usize;
                (total, warmup)
            },
            |&(total, warmup)| {
                for sched in [
                    Schedule::LinearWarmupDecay,
                    Schedule::WarmupCosine { min_frac: 0.01 },
                ] {
                    let mut prev = f64::INFINITY;
                    for step in warmup..total {
                        let lr = sched.lr(step, total, warmup, 1e-3);
                        if lr > prev + 1e-12 {
                            return Err(format!("{sched:?} rose at step {step}"));
                        }
                        if lr < 0.0 {
                            return Err(format!("{sched:?} negative at {step}"));
                        }
                        prev = lr;
                    }
                }
                Ok(())
            },
        );
    }
}
