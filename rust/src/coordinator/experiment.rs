//! Multi-seed experiment pipelines: train → FP eval → outlier metrics →
//! PTQ → quantized eval, aggregated as mean±std — the unit behind every
//! row of every reproduced table.
//!
//! Trained models are cached in `runs/` keyed by the full training recipe,
//! so the many tables sharing a baseline (e.g. vanilla BERT appears in
//! Tables 1, 2, 5, 10 and Figs 1, 2) train it once.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::coordinator::calibrator::{outlier_metrics, CollectOptions};
use crate::coordinator::evaluator::{evaluate, EvalResult};
use crate::coordinator::quantize::{quantized_eval, QuantSpec};
use crate::coordinator::schedule::Schedule;
use crate::coordinator::trainer::{train_fresh, TrainOptions};
use crate::data::batch::{make_provider, Stream, EVAL_SEED};
use crate::runtime::artifact::Artifact;
use crate::runtime::client::Runtime;
use crate::util::log;
use crate::util::stats::MeanStd;
use crate::util::tensor::Tensor;
use crate::util::tensorio;

/// Everything defining one table row (minus the seed).
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    pub config: String,
    pub label: String,
    pub steps: usize,
    pub lr_max: f64,
    pub warmup: usize,
    pub gamma: f32,
    pub zeta: f32,
    pub gate_scale: f32,
    pub b_init: f32,
    pub wd_ln: f32,
    pub act_reg: f32,
    pub seeds: Vec<u64>,
    /// PTQ repetitions per trained model (paper: 3 random calib subsets).
    pub ptq_reps: usize,
    pub quant: QuantSpec,
    pub eval_batches: usize,
    pub metric_batches: usize,
}

impl ExperimentSpec {
    /// Family-appropriate defaults at this testbed's scale.
    pub fn new(config: &str, label: &str, steps: usize) -> ExperimentSpec {
        let is_vit = config.starts_with("vit");
        ExperimentSpec {
            config: config.to_string(),
            label: label.to_string(),
            steps,
            lr_max: 1e-3,
            warmup: (steps / 10).max(1),
            gamma: 0.0,
            zeta: 1.0,
            gate_scale: 1.0,
            b_init: 0.0,
            wd_ln: if config.starts_with("opt") { 1.0 } else { 0.0 },
            act_reg: 0.0,
            seeds: vec![0, 1],
            ptq_reps: 1,
            quant: if config.starts_with("opt") {
                QuantSpec {
                    w_est: crate::quant::estimators::EstimatorKind::Mse,
                    ..QuantSpec::w8a8()
                }
            } else {
                QuantSpec::w8a8()
            },
            eval_batches: 16,
            metric_batches: if is_vit { 8 } else { 8 },
        }
    }

    pub fn with_gamma(mut self, gamma: f32) -> Self {
        self.gamma = gamma;
        self
    }

    pub fn with_zeta(mut self, zeta: f32) -> Self {
        self.zeta = zeta;
        self
    }

    pub fn with_binit(mut self, b: f32) -> Self {
        self.b_init = b;
        self
    }

    pub fn with_seeds(mut self, seeds: Vec<u64>) -> Self {
        self.seeds = seeds;
        self
    }

    pub fn with_quant(mut self, q: QuantSpec) -> Self {
        self.quant = q;
        self
    }

    pub fn with_wd_ln(mut self, w: f32) -> Self {
        self.wd_ln = w;
        self
    }

    fn train_options(&self, seed: u64) -> TrainOptions {
        let schedule = if self.config.starts_with("vit") {
            Schedule::WarmupCosine { min_frac: 0.01 }
        } else {
            Schedule::LinearWarmupDecay
        };
        TrainOptions {
            seed,
            steps: self.steps,
            lr_max: self.lr_max,
            warmup: self.warmup,
            schedule,
            gamma: self.gamma,
            zeta: self.zeta,
            gate_scale: self.gate_scale,
            b_init: self.b_init,
            wd_ln: self.wd_ln,
            act_reg: self.act_reg,
            log_every: 200,
            init_from: Vec::new(),
        }
    }

    /// Cache key for a trained model — everything that affects training.
    pub fn run_key(&self, seed: u64) -> String {
        format!(
            "{}_s{}_st{}_lr{:.0e}_w{}_g{:+.5}_z{:.4}_gs{:.2}_b{:+.2}_wdln{:.0}_ar{:.0e}",
            self.config,
            seed,
            self.steps,
            self.lr_max,
            self.warmup,
            self.gamma,
            self.zeta,
            self.gate_scale,
            self.b_init,
            self.wd_ln,
            self.act_reg
        )
    }
}

/// Per-seed measurements.
#[derive(Debug, Clone)]
pub struct SeedResult {
    pub seed: u64,
    pub fp: EvalResult,
    pub max_inf_norm: f64,
    pub avg_kurtosis: f64,
    pub quant: Vec<EvalResult>,
    pub final_train_loss: f64,
}

/// Aggregated row: the four columns every paper table reports.
#[derive(Debug, Clone)]
pub struct RowResult {
    pub label: String,
    pub fp_metric: MeanStd,
    pub max_inf_norm: MeanStd,
    pub avg_kurtosis: MeanStd,
    pub quant_metric: MeanStd,
    pub seeds: Vec<SeedResult>,
}

/// Load a cached trained model or train and cache it.
pub fn train_cached(
    rt: &Runtime,
    art: &Artifact,
    spec: &ExperimentSpec,
    seed: u64,
    runs_dir: &Path,
) -> Result<Vec<(String, Tensor)>> {
    let path = runs_dir.join(format!("{}.ckpt", spec.run_key(seed)));
    if path.exists() {
        log::info(&format!("reusing cached run {:?}", path.file_name().unwrap()));
        return tensorio::load(&path);
    }
    let opts = spec.train_options(seed);
    let t0 = std::time::Instant::now();
    let result = train_fresh(rt, art, &opts)?;
    log::info(&format!(
        "trained {} seed {seed}: final loss {:.4}, {:.1} steps/s, {:.0}s",
        spec.run_key(seed),
        result.losses.last().copied().unwrap_or(f32::NAN),
        result.steps_per_sec,
        t0.elapsed().as_secs_f64()
    ));
    tensorio::save(&path, &result.params)?;
    // Persist the loss curve alongside (end-to-end example + debugging).
    let curve: String = result
        .losses
        .iter()
        .enumerate()
        .map(|(i, l)| format!("{i},{l}\n"))
        .collect();
    std::fs::write(path.with_extension("loss.csv"), curve)?;
    Ok(result.params)
}

/// Run the full pipeline for one spec (loads the artifact fresh; prefer
/// [`run_experiment_on`] with an [`ArtifactCache`] when running many rows
/// over few configs).
pub fn run_experiment(
    rt: &Runtime,
    artifacts_root: &Path,
    runs_dir: &Path,
    spec: &ExperimentSpec,
) -> Result<RowResult> {
    let art = Artifact::load(artifacts_root, &spec.config)
        .with_context(|| format!("experiment {}", spec.label))?;
    run_experiment_on(rt, &art, runs_dir, spec)
}

/// Artifact cache: compiled executables are reused across the many table
/// rows that share a config (γ/ζ/π_init are runtime inputs).
#[derive(Default)]
pub struct ArtifactCache {
    map: std::cell::RefCell<std::collections::HashMap<String, std::rc::Rc<Artifact>>>,
}

impl ArtifactCache {
    pub fn get(&self, root: &Path, config: &str) -> Result<std::rc::Rc<Artifact>> {
        if let Some(a) = self.map.borrow().get(config) {
            return Ok(a.clone());
        }
        let a = std::rc::Rc::new(Artifact::load(root, config)?);
        self.map.borrow_mut().insert(config.to_string(), a.clone());
        Ok(a)
    }
}

/// Run the full pipeline for one spec against a pre-loaded artifact.
pub fn run_experiment_on(
    rt: &Runtime,
    art: &Artifact,
    runs_dir: &Path,
    spec: &ExperimentSpec,
) -> Result<RowResult> {
    let family = art.manifest.config.family.clone();
    let copts = CollectOptions {
        gamma: spec.gamma,
        zeta: spec.zeta,
        gate_scale: spec.gate_scale,
    };

    let mut seeds = Vec::new();
    for &seed in &spec.seeds {
        let params = train_cached(rt, art, spec, seed, runs_dir)?;

        // FP eval on the shared validation stream.
        let mut eval_provider = make_provider(&art.manifest.config, EVAL_SEED, Stream::Eval);
        let fp = evaluate(
            rt,
            art,
            &params,
            eval_provider.as_mut(),
            spec.eval_batches,
            spec.gamma,
            spec.zeta,
            spec.gate_scale,
        )?;

        // Outlier metrics (§5) on the validation stream.
        let om = outlier_metrics(
            rt,
            art,
            &params,
            eval_provider.as_mut(),
            spec.metric_batches,
            &copts,
        )?;

        // PTQ, repeated over calibration subsets.
        let mut quant = Vec::new();
        for rep in 0..spec.ptq_reps.max(1) {
            let out = quantized_eval(
                rt,
                art,
                &params,
                &spec.quant,
                spec.gamma,
                spec.zeta,
                spec.gate_scale,
                spec.eval_batches,
                seed.wrapping_mul(1000).wrapping_add(rep as u64 + 1),
            )?;
            quant.push(out.result);
        }

        log::info(&format!(
            "{} seed {seed}: fp {:.4} | inf {:.1} kurt {:.1} | quant {:.4}",
            spec.label,
            fp.headline(&family),
            om.max_inf_norm(),
            om.avg_kurtosis(),
            quant[0].headline(&family),
        ));
        seeds.push(SeedResult {
            seed,
            fp,
            max_inf_norm: om.max_inf_norm(),
            avg_kurtosis: om.avg_kurtosis(),
            quant,
            final_train_loss: 0.0,
        });
    }

    let fp_metric = MeanStd::from(
        &seeds.iter().map(|s| s.fp.headline(&family)).collect::<Vec<_>>(),
    );
    let max_inf_norm = MeanStd::from(&seeds.iter().map(|s| s.max_inf_norm).collect::<Vec<_>>());
    let avg_kurtosis = MeanStd::from(&seeds.iter().map(|s| s.avg_kurtosis).collect::<Vec<_>>());
    let quant_metric = MeanStd::from(
        &seeds
            .iter()
            .flat_map(|s| s.quant.iter().map(|q| q.headline(&family)))
            .collect::<Vec<_>>(),
    );
    Ok(RowResult {
        label: spec.label.clone(),
        fp_metric,
        max_inf_norm,
        avg_kurtosis,
        quant_metric,
        seeds,
    })
}

/// Standard artifact/run locations (overridable via env).
pub fn default_paths() -> (PathBuf, PathBuf) {
    let root = std::env::var("QTX_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let runs = std::env::var("QTX_RUNS").unwrap_or_else(|_| "runs".into());
    (PathBuf::from(root), PathBuf::from(runs))
}

/// Find any cached checkpoint for `config`/`seed` in `runs_dir`,
/// independent of the full training recipe (run keys embed every
/// hyperparameter — `{config}_s{seed}_st{steps}_...` — but the
/// artifact-gated serve tests and benches only need *a* trained model for
/// the config). Lexically first match, for determinism.
pub fn find_checkpoint(runs_dir: &Path, config: &str, seed: u64) -> Option<PathBuf> {
    let prefix = format!("{config}_s{seed}_");
    let mut hits: Vec<PathBuf> = std::fs::read_dir(runs_dir)
        .ok()?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.extension().is_some_and(|x| x == "ckpt")
                && p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with(&prefix))
        })
        .collect();
    hits.sort();
    hits.into_iter().next()
}
