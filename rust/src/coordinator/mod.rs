//! The L3 coordinator: everything between "artifacts exist" and "paper
//! table row printed".
//!
//! * [`schedule`] — learning-rate schedules (§C: linear warmup+decay for
//!   BERT/OPT, cosine for ViT).
//! * [`trainer`] — the training loop over the AOT `train_step` program;
//!   state lives as XLA literals threaded output→input between steps.
//! * [`evaluator`] — floating-point evaluation (perplexity / accuracy).
//! * [`calibrator`] — runs `act_collect` to (a) estimate activation
//!   quantization ranges and (b) accumulate the §5 outlier metrics.
//! * [`quantize`] — the full PTQ pipeline: weight fake-quant + activation
//!   calibration + quantized evaluation via `eval_quant`.
//! * [`experiment`] — multi-seed train→eval→PTQ pipelines with checkpoint
//!   caching and mean±std aggregation; the unit every bench row is built
//!   from.

pub mod calibrator;
pub mod evaluator;
pub mod experiment;
pub mod quantize;
pub mod schedule;
pub mod trainer;

pub use experiment::{ExperimentSpec, RowResult};
pub use quantize::QuantSpec;
pub use trainer::TrainOptions;
