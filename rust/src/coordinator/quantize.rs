//! The full PTQ pipeline (§5 "Quantization setup"):
//!
//! 1. **weights** — symmetric per-tensor fake-quant on the host (min-max,
//!    or MSE for OPT / low-bit per §C.4 + App. B.7), final head excluded;
//! 2. **activations** — static asymmetric ranges from calibration batches
//!    (estimator selectable), fed as scale/zero-point vectors into the
//!    `eval_quant` program together with `qmax = 2^a_bits − 1`;
//! 3. **quantized eval** — perplexity / accuracy on the eval stream.

use anyhow::Result;

use crate::coordinator::calibrator::{calibrate, CollectOptions};
use crate::coordinator::evaluator::{param_literals, run_eval_program, EvalResult};
use crate::data::batch::{make_provider, Stream};
use crate::quant::estimators::EstimatorKind;
use crate::quant::weights::fake_quant_weight;
use crate::runtime::artifact::Artifact;
use crate::runtime::client::Runtime;
use crate::runtime::program::Value;
use crate::util::tensor::Tensor;

#[derive(Debug, Clone, Copy)]
pub struct QuantSpec {
    pub w_bits: u32,
    pub a_bits: u32,
    pub w_est: EstimatorKind,
    pub a_est: EstimatorKind,
    pub calib_batches: usize,
}

impl QuantSpec {
    /// The paper's default W8A8 setup: min-max weights, 99.999-percentile
    /// activations (§C.4's best-performing configuration), 16 calibration
    /// batches.
    pub fn w8a8() -> QuantSpec {
        QuantSpec {
            w_bits: 8,
            a_bits: 8,
            w_est: EstimatorKind::MinMax,
            a_est: EstimatorKind::Percentile { pct: 99.999 },
            calib_batches: 16,
        }
    }

    pub fn label(&self) -> String {
        format!(
            "W{}A{} (w:{}, a:{})",
            self.w_bits,
            self.a_bits,
            self.w_est.name(),
            self.a_est.name()
        )
    }
}

/// Fake-quantize the quantizable weights (manifest `quantize` flag).
pub fn quantize_weights(
    art: &Artifact,
    params: &[(String, Tensor)],
    kind: EstimatorKind,
    bits: u32,
) -> Vec<(String, Tensor)> {
    params
        .iter()
        .map(|(name, t)| {
            let quantize = art
                .manifest
                .params
                .iter()
                .find(|p| &p.name == name)
                .map(|p| p.quantize)
                .unwrap_or(false);
            let t2 = if quantize { fake_quant_weight(t, kind, bits) } else { t.clone() };
            (name.clone(), t2)
        })
        .collect()
}

pub struct QuantOutcome {
    pub result: EvalResult,
    /// Per-point (scale, zero_point) actually used.
    pub act_scales: Vec<f32>,
    pub act_zps: Vec<f32>,
}

/// Run the full PTQ pipeline for one trained model.
///
/// `ptq_seed` varies the calibration stream (the paper repeats PTQ with 3
/// random calibration subsets and reports mean±std).
#[allow(clippy::too_many_arguments)]
pub fn quantized_eval(
    rt: &Runtime,
    art: &Artifact,
    params: &[(String, Tensor)],
    spec: &QuantSpec,
    gamma: f32,
    zeta: f32,
    gate_scale: f32,
    eval_batches: usize,
    ptq_seed: u64,
) -> Result<QuantOutcome> {
    let cfg = &art.manifest.config;
    let copts = CollectOptions { gamma, zeta, gate_scale };

    // 1. weights
    let wq = quantize_weights(art, params, spec.w_est, spec.w_bits);

    // 2. activation calibration — on the *weight-quantized* model, matching
    // deployment (the quantized network is what runs at inference).
    let mut calib_provider = make_provider(cfg, ptq_seed, Stream::Calibration);
    let cal = calibrate(
        rt,
        art,
        &wq,
        calib_provider.as_mut(),
        spec.calib_batches,
        spec.a_est,
        &copts,
        ptq_seed,
    )?;
    let qp = cal.finalize(spec.a_bits);
    let act_scales: Vec<f32> = qp.iter().map(|q| q.scale).collect();
    let act_zps: Vec<f32> = qp.iter().map(|q| q.zero_point).collect();

    // 3. quantized eval
    let prog = art.program(rt, "eval_quant")?;
    let param_lits = param_literals(&prog, &wq)?;
    let n = act_scales.len();
    let mut eval_provider = make_provider(cfg, crate::data::batch::EVAL_SEED, Stream::Eval);
    let result = run_eval_program(
        &prog,
        &param_lits,
        eval_provider.as_mut(),
        eval_batches,
        &[
            ("act_scale", Value::F32(Tensor::new(vec![n], act_scales.clone())?)),
            ("act_zp", Value::F32(Tensor::new(vec![n], act_zps.clone())?)),
            ("qmax", Value::scalar(crate::quant::grid::qmax_for_bits(spec.a_bits))),
            ("gamma", Value::scalar(gamma)),
            ("zeta", Value::scalar(zeta)),
            ("gate_scale", Value::scalar(gate_scale)),
        ],
    )?;
    Ok(QuantOutcome { result, act_scales, act_zps })
}
