//! Floating-point evaluation: run `eval_step` over a deterministic eval
//! stream and aggregate NLL / accuracy.

use anyhow::{bail, Result};

use crate::data::batch::Provider;
use crate::metrics::perplexity;
use crate::runtime::artifact::Artifact;
use crate::runtime::client::Runtime;
use crate::runtime::program::{literal_scalar_f32, Program, Value};
use crate::util::tensor::Tensor;

#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub loss: f64,
    pub ppl: f64,
    pub accuracy: f64,
    pub tokens: f64,
}

impl EvalResult {
    /// The headline task metric: perplexity for LMs (↓), top-1 accuracy in
    /// percent for ViT (↑) — matching what each paper table reports.
    pub fn headline(&self, family: &str) -> f64 {
        if family == "vit" {
            self.accuracy * 100.0
        } else {
            self.ppl
        }
    }
}

/// Build the `param::*` literal list for an eval-style program from named
/// host tensors (they must cover the program's param inputs exactly).
pub fn param_literals(
    prog: &Program,
    params: &[(String, Tensor)],
) -> Result<Vec<xla::Literal>> {
    let mut lits = Vec::new();
    for d in &prog.inputs {
        if let Some(pname) = d.name.strip_prefix("param::") {
            let (_, t) = params
                .iter()
                .find(|(n, _)| n == pname)
                .ok_or_else(|| anyhow::anyhow!("missing param {pname:?}"))?;
            if t.shape() != d.shape.as_slice() {
                bail!("param {pname}: shape {:?} != manifest {:?}", t.shape(), d.shape);
            }
            lits.push(Value::F32(t.clone()).to_literal()?);
        }
    }
    Ok(lits)
}

/// Run `prog` (eval_step-shaped: params, batch, hypers -> nll/count/correct)
/// over `n_batches` from the (reset) provider. `extra` supplies non-param,
/// non-batch inputs by name.
pub fn run_eval_program(
    prog: &Program,
    param_lits: &[xla::Literal],
    provider: &mut dyn Provider,
    n_batches: usize,
    extra: &[(&str, Value)],
) -> Result<EvalResult> {
    provider.reset();
    let extra_lits: Vec<(String, xla::Literal)> = extra
        .iter()
        .map(|(n, v)| Ok((n.to_string(), v.to_literal()?)))
        .collect::<Result<_>>()?;

    let (i_nll, i_count, i_correct) = (
        prog.output_index("sum_nll")?,
        prog.output_index("count")?,
        prog.output_index("correct")?,
    );

    let mut sum_nll = 0.0f64;
    let mut count = 0.0f64;
    let mut correct = 0.0f64;
    for _ in 0..n_batches {
        let batch = provider.next_batch();
        let batch_lits: Vec<(String, xla::Literal)> = batch
            .values
            .iter()
            .map(|(n, v)| Ok((n.to_string(), v.to_literal()?)))
            .collect::<Result<_>>()?;
        // Assemble in program input order.
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(prog.inputs.len());
        let mut pi = 0;
        for d in &prog.inputs {
            if d.name.starts_with("param::") {
                args.push(&param_lits[pi]);
                pi += 1;
            } else if let Some((_, l)) = batch_lits.iter().find(|(n, _)| *n == d.name) {
                args.push(l);
            } else if let Some((_, l)) = extra_lits.iter().find(|(n, _)| *n == d.name) {
                args.push(l);
            } else {
                bail!("{}: no source for input {:?}", prog.name, d.name);
            }
        }
        let out = prog.run_raw(&args)?;
        sum_nll += literal_scalar_f32(&out[i_nll])? as f64;
        count += literal_scalar_f32(&out[i_count])? as f64;
        correct += literal_scalar_f32(&out[i_correct])? as f64;
    }
    Ok(EvalResult {
        loss: sum_nll / count.max(1.0),
        ppl: perplexity(sum_nll, count),
        accuracy: correct / count.max(1.0),
        tokens: count,
    })
}

/// FP eval entry point.
pub fn evaluate(
    rt: &Runtime,
    art: &Artifact,
    params: &[(String, Tensor)],
    provider: &mut dyn Provider,
    n_batches: usize,
    gamma: f32,
    zeta: f32,
    gate_scale: f32,
) -> Result<EvalResult> {
    let prog = art.program(rt, "eval_step")?;
    let lits = param_literals(&prog, params)?;
    run_eval_program(
        &prog,
        &lits,
        provider,
        n_batches,
        &[
            ("gamma", Value::scalar(gamma)),
            ("zeta", Value::scalar(zeta)),
            ("gate_scale", Value::scalar(gate_scale)),
        ],
    )
}
