//! The training loop over the AOT `train_step` program.
//!
//! Optimizer state (params, Adam moments, step counter) is threaded as XLA
//! literals from one step's output tuple into the next step's inputs; the
//! only per-step host work is batch synthesis (rust-side anyway), the lr
//! scalar, and reading back the loss.

use anyhow::{bail, Context, Result};

use crate::coordinator::schedule::Schedule;
use crate::data::batch::{self, Provider, Stream};
use crate::runtime::artifact::Artifact;
use crate::runtime::client::Runtime;
use crate::runtime::program::{literal_scalar_f32, literal_to_value, Value};
use crate::util::log;
use crate::util::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub seed: u64,
    pub steps: usize,
    pub lr_max: f64,
    pub warmup: usize,
    pub schedule: Schedule,
    /// Clipped-softmax stretch factors (γ=0, ζ=1 ⇒ vanilla softmax).
    pub gamma: f32,
    pub zeta: f32,
    /// Gate-output multiplier (2.0 in the §B.6 fine-tuning recipe).
    pub gate_scale: f32,
    /// Gating bias init — controls π_init = sigmoid(b_init) (Fig 7).
    pub b_init: f32,
    /// Weight decay on LayerNorm γ (Table 6), 0.0 or 1.0.
    pub wd_ln: f32,
    /// FFN-output activation regularization coefficient (§B.6).
    pub act_reg: f32,
    pub log_every: usize,
    /// Warm-start parameters by name (fine-tuning, §B.6): params present
    /// here override the fresh init; everything else (e.g. newly added
    /// gating modules) keeps its init.
    pub init_from: Vec<(String, Tensor)>,
}

impl TrainOptions {
    pub fn new(seed: u64, steps: usize) -> TrainOptions {
        TrainOptions {
            seed,
            steps,
            lr_max: 1e-3,
            warmup: steps / 10,
            schedule: Schedule::LinearWarmupDecay,
            gamma: 0.0,
            zeta: 1.0,
            gate_scale: 1.0,
            b_init: 0.0,
            wd_ln: 0.0,
            act_reg: 0.0,
            log_every: 100,
            init_from: Vec::new(),
        }
    }
}

pub struct TrainResult {
    /// Trained parameters in manifest order: (name, tensor).
    pub params: Vec<(String, Tensor)>,
    /// Loss at every step.
    pub losses: Vec<f32>,
    pub steps_per_sec: f64,
}

/// Where each train_step input comes from.
enum Src {
    State(usize),
    Batch(usize),
    Lr,
    Const(f32),
}

pub fn train(
    rt: &Runtime,
    art: &Artifact,
    opts: &TrainOptions,
    provider: &mut dyn Provider,
) -> Result<TrainResult> {
    let init = art.program(rt, "init")?;
    let step_prog = art.program(rt, "train_step")?;
    let cfg = &art.manifest.config;

    // --- initialize state ------------------------------------------------
    // init outputs param::* in manifest order; m/v start at zero; step at 0.
    let init_out = init.run(&[
        Value::scalar_i32(opts.seed as i32),
        Value::scalar(opts.b_init),
    ])?;
    let n_params = art.manifest.params.len();
    if init_out.len() != n_params {
        bail!("init returned {} tensors, manifest has {n_params}", init_out.len());
    }

    // State literal vector ordered exactly like train_step's outputs
    // (param::*, m::*, v::*, step). Loss is output-only.
    let out_descs = &step_prog.outputs;
    let loss_idx = step_prog.output_index("loss")?;
    let n_state = out_descs.len() - 1;
    let mut state: Vec<xla::Literal> = Vec::with_capacity(n_state);
    for d in out_descs.iter().take(n_state) {
        if let Some(pname) = d.name.strip_prefix("param::") {
            let pi = art
                .manifest
                .params
                .iter()
                .position(|p| p.name == pname)
                .with_context(|| format!("output {:?} not a manifest param", d.name))?;
            // Warm-start override (fine-tuning), else fresh init. init
            // outputs are in manifest param order.
            if let Some((_, t)) = opts.init_from.iter().find(|(n, _)| n == pname) {
                if t.shape() != d.shape.as_slice() {
                    bail!("init_from {pname}: shape {:?} != {:?}", t.shape(), d.shape);
                }
                state.push(Value::F32(t.clone()).to_literal()?);
            } else {
                state.push(init_out[pi].clone());
            }
        } else if d.name.starts_with("m::") || d.name.starts_with("v::") {
            state.push(Value::F32(Tensor::zeros(&d.shape)).to_literal()?);
        } else if d.name == "step" {
            state.push(Value::scalar(0.0).to_literal()?);
        } else {
            bail!("unexpected train_step output {:?}", d.name);
        }
    }

    // --- input plan -------------------------------------------------------
    // Map each train_step input to a source, once.
    let mut plan: Vec<Src> = Vec::with_capacity(step_prog.inputs.len());
    let state_index = |name: &str| -> Option<usize> {
        out_descs.iter().take(n_state).position(|d| d.name == name)
    };
    let probe = provider.next_batch(); // names only
    let batch_names: Vec<&'static str> = probe.values.iter().map(|(n, _)| *n).collect();
    for d in &step_prog.inputs {
        let src = if let Some(si) = state_index(&d.name) {
            Src::State(si)
        } else if let Some(bi) = batch_names.iter().position(|n| *n == d.name) {
            Src::Batch(bi)
        } else {
            match d.name.as_str() {
                "lr" => Src::Lr,
                "gamma" => Src::Const(opts.gamma),
                "zeta" => Src::Const(opts.zeta),
                "gate_scale" => Src::Const(opts.gate_scale),
                "wd_ln" => Src::Const(opts.wd_ln),
                "act_reg" => Src::Const(opts.act_reg),
                other => bail!("train_step input {other:?} has no source"),
            }
        };
        plan.push(src);
    }

    // Constant literals prepared once.
    let const_lits: Vec<Option<xla::Literal>> = plan
        .iter()
        .map(|s| match s {
            Src::Const(v) => Some(Value::scalar(*v).to_literal().unwrap()),
            _ => None,
        })
        .collect();

    // --- loop --------------------------------------------------------------
    let mut losses = Vec::with_capacity(opts.steps);
    let t0 = std::time::Instant::now();
    let mut batch = probe; // consume the probe batch as step 0 data
    for step in 0..opts.steps {
        let lr = opts.schedule.lr(step, opts.steps, opts.warmup, opts.lr_max);
        let lr_lit = Value::scalar(lr as f32).to_literal()?;
        let batch_lits: Vec<xla::Literal> = batch
            .values
            .iter()
            .map(|(_, v)| v.to_literal())
            .collect::<Result<_>>()?;

        let args: Vec<&xla::Literal> = plan
            .iter()
            .enumerate()
            .map(|(i, s)| match s {
                Src::State(si) => &state[*si],
                Src::Batch(bi) => &batch_lits[*bi],
                Src::Lr => &lr_lit,
                Src::Const(_) => const_lits[i].as_ref().unwrap(),
            })
            .collect();

        let mut out = step_prog.run_raw(&args)?;
        let loss = literal_scalar_f32(&out[loss_idx])?;
        if !loss.is_finite() {
            bail!("non-finite loss {loss} at step {step} ({})", cfg.name);
        }
        losses.push(loss);
        out.truncate(n_state);
        state = out;

        if opts.log_every > 0 && (step + 1) % opts.log_every == 0 {
            let recent = &losses[losses.len().saturating_sub(opts.log_every)..];
            log::info(&format!(
                "{} step {}/{} loss {:.4} lr {:.2e}",
                cfg.name,
                step + 1,
                opts.steps,
                recent.iter().sum::<f32>() / recent.len() as f32,
                lr
            ));
        }
        batch = provider.next_batch();
    }
    let elapsed = t0.elapsed().as_secs_f64();

    // --- extract parameters -------------------------------------------------
    let mut params = Vec::with_capacity(n_params);
    for (d, lit) in out_descs.iter().take(n_state).zip(&state) {
        if let Some(pname) = d.name.strip_prefix("param::") {
            match literal_to_value(lit)? {
                Value::F32(t) => params.push((pname.to_string(), t)),
                _ => bail!("param {pname} not f32"),
            }
        }
    }
    if params.len() != n_params {
        bail!("extracted {} params, expected {n_params}", params.len());
    }

    Ok(TrainResult {
        params,
        losses,
        steps_per_sec: opts.steps as f64 / elapsed.max(1e-9),
    })
}

/// Convenience: train on the standard Train stream for the config.
pub fn train_fresh(
    rt: &Runtime,
    art: &Artifact,
    opts: &TrainOptions,
) -> Result<TrainResult> {
    let mut provider = batch::make_provider(&art.manifest.config, opts.seed, Stream::Train);
    train(rt, art, opts, provider.as_mut())
}
