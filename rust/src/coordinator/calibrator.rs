//! Runs the `act_collect` program to gather activations, serving three
//! consumers:
//!
//! 1. the PTQ **calibrator** (activation ranges per quant point, §C.4 —
//!    16 calibration batches by default);
//! 2. the §5 **outlier metrics** (max inf-norm, avg kurtosis over block
//!    outputs, computed on the eval stream);
//! 3. the **analysis** dumps (attention probs / values / gates for the
//!    Fig 1-3/8 reproductions).

use anyhow::{Context, Result};

use crate::data::batch::Provider;
use crate::metrics::OutlierMetrics;
use crate::quant::estimators::{Calibration, EstimatorKind};
use crate::runtime::artifact::Artifact;
use crate::runtime::client::Runtime;
use crate::runtime::program::{literal_to_value, Value};
use crate::util::tensor::Tensor;

/// One collected batch of named activations (on demand for analysis).
pub struct ActBatch {
    /// (tap name, tensor), tap names without the `act::` prefix.
    pub acts: Vec<(String, Tensor)>,
    /// The token batch that produced them (token families).
    pub tokens: Option<Vec<i32>>,
}

impl ActBatch {
    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.acts.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }
}

pub struct CollectOptions {
    pub gamma: f32,
    pub zeta: f32,
    pub gate_scale: f32,
}

/// Stream act_collect over `n_batches`, invoking `sink` per batch.
pub fn collect(
    rt: &Runtime,
    art: &Artifact,
    params: &[(String, Tensor)],
    provider: &mut dyn Provider,
    n_batches: usize,
    opts: &CollectOptions,
    mut sink: impl FnMut(&ActBatch) -> Result<()>,
) -> Result<()> {
    let prog = art.program(rt, "act_collect")?;
    let param_lits = super::evaluator::param_literals(&prog, params)?;
    let extra = [
        ("gamma", Value::scalar(opts.gamma)),
        ("zeta", Value::scalar(opts.zeta)),
        ("gate_scale", Value::scalar(opts.gate_scale)),
    ];
    let extra_lits: Vec<(String, xla::Literal)> = extra
        .iter()
        .map(|(n, v)| Ok((n.to_string(), v.to_literal()?)))
        .collect::<Result<_>>()?;
    provider.reset();
    for _ in 0..n_batches {
        let batch = provider.next_batch();
        let tokens = batch.values.iter().find(|(n, _)| *n == "batch::x").and_then(
            |(_, v)| match v {
                Value::I32(t) => Some(t.data().to_vec()),
                _ => None,
            },
        );
        let batch_lits: Vec<(String, xla::Literal)> = batch
            .values
            .iter()
            .map(|(n, v)| Ok((n.to_string(), v.to_literal()?)))
            .collect::<Result<_>>()?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(prog.inputs.len());
        let mut pi = 0;
        for d in &prog.inputs {
            if d.name.starts_with("param::") {
                args.push(&param_lits[pi]);
                pi += 1;
            } else if let Some((_, l)) = batch_lits.iter().find(|(n, _)| *n == d.name) {
                args.push(l);
            } else if let Some((_, l)) = extra_lits.iter().find(|(n, _)| *n == d.name) {
                args.push(l);
            } else {
                anyhow::bail!("{}: no source for input {:?}", prog.name, d.name);
            }
        }
        let out = prog.run_raw(&args)?;
        let mut acts = Vec::new();
        for (desc, lit) in prog.outputs.iter().zip(&out) {
            if let Some(name) = desc.name.strip_prefix("act::") {
                match literal_to_value(lit)? {
                    Value::F32(t) => acts.push((name.to_string(), t)),
                    _ => anyhow::bail!("activation {name} not f32"),
                }
            }
        }
        sink(&ActBatch { acts, tokens })?;
    }
    Ok(())
}

/// Calibrate activation ranges on the calibration stream (§C.4).
pub fn calibrate(
    rt: &Runtime,
    art: &Artifact,
    params: &[(String, Tensor)],
    provider: &mut dyn Provider,
    n_batches: usize,
    kind: EstimatorKind,
    opts: &CollectOptions,
    seed: u64,
) -> Result<Calibration> {
    let points = &art.manifest.quant_points;
    let mut cal = Calibration::new(kind, points.len(), seed);
    collect(rt, art, params, provider, n_batches, opts, |ab| {
        for (i, pname) in points.iter().enumerate() {
            let t = ab
                .get(pname)
                .with_context(|| format!("quant point {pname:?} missing from act_collect"))?;
            cal.observe(i, t.data());
        }
        Ok(())
    })?;
    Ok(cal)
}

/// Outlier metrics (§5) over the eval stream: block outputs per layer.
pub fn outlier_metrics(
    rt: &Runtime,
    art: &Artifact,
    params: &[(String, Tensor)],
    provider: &mut dyn Provider,
    n_batches: usize,
    opts: &CollectOptions,
) -> Result<OutlierMetrics> {
    let n_layers = art.manifest.config.n_layers;
    let mut m = OutlierMetrics::default();
    collect(rt, art, params, provider, n_batches, opts, |ab| {
        let mut layers: Vec<(String, &[f32])> = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let name = format!("L{l}.block_out");
            let t = ab
                .get(&name)
                .with_context(|| format!("{name} missing from act_collect"))?;
            layers.push((name, t.data()));
        }
        m.observe_batch(&layers);
        Ok(())
    })?;
    Ok(m)
}
