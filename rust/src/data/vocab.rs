//! Vocabulary layout for the synthetic delimiter language.
//!
//! Special tokens occupy the low ids; everything from [`FIRST_CONTENT`] up
//! is a content token. Content ids are partitioned into [`N_TOPICS`] equal
//! "topics" — phrases stay within a topic, giving the bigram model its
//! local structure.

pub const PAD: i32 = 0;
pub const CLS: i32 = 1;
pub const SEP: i32 = 2;
pub const MASK: i32 = 3;
pub const PERIOD: i32 = 4;
pub const COMMA: i32 = 5;
pub const FIRST_CONTENT: i32 = 6;

/// Delimiters: the low-information tokens the paper's no-op heads attend to
/// (Fig 2 shows [SEP], ".", "," absorbing almost all probability mass).
pub const DELIMITERS: [i32; 3] = [SEP, PERIOD, COMMA];

pub const N_TOPICS: usize = 8;

pub fn is_special(tok: i32) -> bool {
    tok < FIRST_CONTENT
}

pub fn is_delimiter(tok: i32) -> bool {
    DELIMITERS.contains(&tok)
}

/// Number of content tokens for a vocab size.
pub fn n_content(vocab_size: usize) -> usize {
    vocab_size - FIRST_CONTENT as usize
}

/// Topic of a content token (consistent with [`topic_range`] even when the
/// content count is not divisible by N_TOPICS).
pub fn topic_of(tok: i32, vocab_size: usize) -> usize {
    debug_assert!(!is_special(tok));
    let n = n_content(vocab_size);
    let c = (tok - FIRST_CONTENT) as usize;
    // ranges are [t*n/N, (t+1)*n/N); invert by scanning boundaries.
    let guess = c * N_TOPICS / n;
    for t in guess.saturating_sub(1)..=(guess + 1).min(N_TOPICS - 1) {
        if t * n / N_TOPICS <= c && c < (t + 1) * n / N_TOPICS {
            return t;
        }
    }
    guess.min(N_TOPICS - 1)
}

/// Content-token id range of a topic: [start, end).
pub fn topic_range(topic: usize, vocab_size: usize) -> (i32, i32) {
    let n = n_content(vocab_size);
    let start = topic * n / N_TOPICS;
    let end = (topic + 1) * n / N_TOPICS;
    (FIRST_CONTENT + start as i32, FIRST_CONTENT + end as i32)
}

/// Human-readable token name (analysis dumps).
pub fn token_name(tok: i32) -> String {
    match tok {
        PAD => "[PAD]".into(),
        CLS => "[CLS]".into(),
        SEP => "[SEP]".into(),
        MASK => "[MASK]".into(),
        PERIOD => ".".into(),
        COMMA => ",".into(),
        t => format!("w{t}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn special_classification() {
        assert!(is_special(CLS));
        assert!(is_special(COMMA));
        assert!(!is_special(FIRST_CONTENT));
        assert!(is_delimiter(SEP));
        assert!(!is_delimiter(CLS));
        assert!(!is_delimiter(FIRST_CONTENT));
    }

    #[test]
    fn topics_partition_content() {
        let v = 256;
        let mut count = 0;
        for topic in 0..N_TOPICS {
            let (lo, hi) = topic_range(topic, v);
            for t in lo..hi {
                assert_eq!(topic_of(t, v), topic, "token {t}");
                count += 1;
            }
        }
        assert_eq!(count, n_content(v));
    }

    #[test]
    fn names() {
        assert_eq!(token_name(SEP), "[SEP]");
        assert_eq!(token_name(42), "w42");
    }
}
