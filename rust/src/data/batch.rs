//! Batch containers and the provider abstraction the coordinator consumes.
//!
//! A [`Provider`] is an infinite, seeded batch stream; train / eval /
//! calibration streams are independent named forks of the experiment seed,
//! so e.g. the §C.4 "16 random calibration batches" are reproducible and
//! disjoint from the eval stream.

use crate::data::clm;
use crate::data::mlm;
use crate::data::textgen::TextGen;
use crate::data::vision;
use crate::runtime::artifact::ConfigInfo;
use crate::runtime::program::Value;
use crate::util::rng::Rng;

/// One model batch in program-input form.
pub struct Batch {
    /// Named values matching the program's `batch::*` inputs.
    pub values: Vec<(&'static str, Value)>,
}

impl Batch {
    pub fn named(&self) -> Vec<(&'static str, Value)> {
        self.values.clone()
    }
}

/// Infinite batch stream.
pub trait Provider {
    fn next_batch(&mut self) -> Batch;
    /// Restart the stream from its initial state (eval determinism).
    fn reset(&mut self);
}

/// Language-model stream (bert MLM / opt CLM).
pub struct TextProvider {
    cfg: ConfigInfo,
    lang_seed: u64,
    stream_seed: u64,
    gen: TextGen,
    mask_rng: Rng,
}

impl TextProvider {
    pub fn new(cfg: &ConfigInfo, lang_seed: u64, stream_seed: u64) -> TextProvider {
        TextProvider {
            cfg: cfg.clone(),
            lang_seed,
            stream_seed,
            gen: TextGen::new(cfg.vocab_size, lang_seed, stream_seed),
            mask_rng: Rng::new(stream_seed).fork("mlm-mask"),
        }
    }
}

impl Provider for TextProvider {
    fn next_batch(&mut self) -> Batch {
        let (b, t) = (self.cfg.batch_size, self.cfg.seq_len);
        let values = match self.cfg.objective.as_str() {
            "mlm" => {
                let m = mlm::make_batch(&mut self.gen, &mut self.mask_rng, b, t, self.cfg.vocab_size);
                vec![
                    ("batch::x", Value::I32(m.tokens)),
                    ("batch::targets", Value::I32(m.targets)),
                    ("batch::mask", Value::F32(m.mask)),
                ]
            }
            "clm" => {
                let m = clm::make_batch(&mut self.gen, b, t);
                vec![
                    ("batch::x", Value::I32(m.tokens)),
                    ("batch::targets", Value::I32(m.targets)),
                    ("batch::mask", Value::F32(m.mask)),
                ]
            }
            other => panic!("TextProvider on objective {other}"),
        };
        Batch { values }
    }

    fn reset(&mut self) {
        self.gen = TextGen::new(self.cfg.vocab_size, self.lang_seed, self.stream_seed);
        self.mask_rng = Rng::new(self.stream_seed).fork("mlm-mask");
    }
}

/// Vision stream (vit classification).
pub struct VisionProvider {
    cfg: ConfigInfo,
    stream_seed: u64,
    rng: Rng,
}

impl VisionProvider {
    pub fn new(cfg: &ConfigInfo, stream_seed: u64) -> VisionProvider {
        VisionProvider {
            cfg: cfg.clone(),
            stream_seed,
            rng: Rng::new(stream_seed).fork("vision"),
        }
    }
}

impl Provider for VisionProvider {
    fn next_batch(&mut self) -> Batch {
        assert_eq!(self.cfg.patch_dim, vision::PATCH_DIM, "config patch_dim mismatch");
        let v = vision::make_batch(&mut self.rng, self.cfg.batch_size);
        Batch {
            values: vec![
                ("batch::x", Value::F32(v.patches)),
                ("batch::targets", Value::I32(v.labels)),
            ],
        }
    }

    fn reset(&mut self) {
        self.rng = Rng::new(self.stream_seed).fork("vision");
    }
}

/// Purpose-labelled stream seeds derived from the experiment seed.
#[derive(Clone, Copy)]
pub enum Stream {
    Train,
    Eval,
    Calibration,
}

impl Stream {
    fn label(self) -> &'static str {
        match self {
            Stream::Train => "train",
            Stream::Eval => "eval",
            Stream::Calibration => "calib",
        }
    }

    fn seed(self, experiment_seed: u64) -> u64 {
        let mut h: u64 = experiment_seed ^ 0x51ab_c0de;
        for b in self.label().bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
        h
    }
}

/// The language (bigram tables) is shared across ALL streams and seeds so
/// that train and eval measure the same task; only the sampled text varies.
pub const LANG_SEED: u64 = 0xBEEF;

/// The validation set is one fixed stream shared by every experiment (the
/// paper evaluates all methods on the same Wikipedia/ImageNet validation
/// split) — always build Eval providers with this seed.
pub const EVAL_SEED: u64 = 0;

pub fn make_provider(cfg: &ConfigInfo, experiment_seed: u64, stream: Stream) -> Box<dyn Provider> {
    let seed = stream.seed(experiment_seed);
    if cfg.family == "vit" {
        Box::new(VisionProvider::new(cfg, seed))
    } else {
        Box::new(TextProvider::new(cfg, LANG_SEED, seed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(objective: &str, family: &str) -> ConfigInfo {
        ConfigInfo {
            name: "t".into(),
            family: family.into(),
            attention: "softmax".into(),
            n_layers: 2,
            d_model: 16,
            n_heads: 2,
            seq_len: 16,
            vocab_size: 256,
            n_classes: 8,
            patch_dim: vision::PATCH_DIM,
            batch_size: 4,
            causal: objective == "clm",
            use_gate: false,
            objective: objective.into(),
        }
    }

    #[test]
    fn streams_are_distinct_and_deterministic() {
        let c = cfg("mlm", "bert");
        let mut train = make_provider(&c, 0, Stream::Train);
        let mut eval = make_provider(&c, 0, Stream::Eval);
        let mut train2 = make_provider(&c, 0, Stream::Train);
        let a = train.next_batch();
        let b = eval.next_batch();
        let a2 = train2.next_batch();
        let tok = |x: &Batch| match &x.values[0].1 {
            Value::I32(t) => t.data().to_vec(),
            _ => panic!(),
        };
        assert_eq!(tok(&a), tok(&a2));
        assert_ne!(tok(&a), tok(&b));
    }

    #[test]
    fn reset_restarts_stream() {
        let c = cfg("clm", "opt");
        let mut p = make_provider(&c, 1, Stream::Eval);
        let a = p.next_batch();
        p.next_batch();
        p.reset();
        let b = p.next_batch();
        let tok = |x: &Batch| match &x.values[0].1 {
            Value::I32(t) => t.data().to_vec(),
            _ => panic!(),
        };
        assert_eq!(tok(&a), tok(&b));
    }

    #[test]
    fn vision_provider_shapes() {
        let c = cfg("cls", "vit");
        let mut p = make_provider(&c, 2, Stream::Train);
        let b = p.next_batch();
        assert_eq!(b.values.len(), 2);
        match &b.values[0].1 {
            Value::F32(t) => assert_eq!(t.shape(), &[4, 16, vision::PATCH_DIM]),
            _ => panic!(),
        }
    }
}
