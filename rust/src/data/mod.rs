//! Synthetic data substrates.
//!
//! The paper trains on BookCorpus+Wikipedia (BERT/OPT) and ImageNet (ViT);
//! neither is available here, so this module implements the closest
//! synthetic equivalents that preserve the *mechanism under study* (see
//! DESIGN.md "Hardware adaptation"):
//!
//! * [`textgen`] — a Markov "delimiter language": topic-local bigram
//!   phrases separated by low-information delimiter tokens (`[SEP]`, `.`,
//!   `,`). Delimiters appear in every sequence and carry no predictive
//!   signal, exactly the token class the paper shows attention heads dump
//!   probability mass on when they want a no-op (Fig 2); bigram-local
//!   dependencies mean deep-layer mixing is often unnecessary, creating the
//!   no-update incentive.
//! * [`vision`] — procedural shapes on noisy backgrounds: most patches are
//!   uninformative background, the patch analogue of delimiters (Fig 3).
//! * [`mlm`] / [`clm`] — BERT-style masking and causal shifting.
//! * [`batch`] — batch containers + the [`batch::Provider`] abstraction the
//!   trainer consumes, with seeded train/eval/calibration stream factories.

pub mod batch;
pub mod clm;
pub mod mlm;
pub mod textgen;
pub mod vision;
pub mod vocab;
