//! BERT-style masked-language-model batch assembly (§C.1: p=0.15 masking,
//! the standard 80/10/10 [MASK]/random/keep split).

use crate::data::textgen::TextGen;
use crate::data::vocab;
use crate::util::rng::Rng;
use crate::util::tensor::{IntTensor, Tensor};

pub const MASK_PROB: f32 = 0.15;

/// One MLM batch: `tokens` (corrupted), `targets` (originals), `mask`
/// (1.0 at predicted positions).
pub struct MlmBatch {
    pub tokens: IntTensor,
    pub targets: IntTensor,
    pub mask: Tensor,
}

/// Assemble a (batch, seq) MLM batch from the generator. Only content
/// tokens are maskable (specials/delimiters carry structural information).
pub fn make_batch(
    gen: &mut TextGen,
    rng: &mut Rng,
    batch: usize,
    seq: usize,
    vocab_size: usize,
) -> MlmBatch {
    let mut tokens = Vec::with_capacity(batch * seq);
    let mut targets = Vec::with_capacity(batch * seq);
    let mut mask = Vec::with_capacity(batch * seq);
    for _ in 0..batch {
        let orig = gen.sequence_with_cls(seq);
        for &t in &orig {
            targets.push(t);
            if !vocab::is_special(t) && rng.bernoulli(MASK_PROB) {
                mask.push(1.0);
                let r = rng.f32();
                if r < 0.8 {
                    tokens.push(vocab::MASK);
                } else if r < 0.9 {
                    tokens.push(rng.range(
                        vocab::FIRST_CONTENT as u32,
                        vocab_size as u32,
                    ) as i32);
                } else {
                    tokens.push(t);
                }
            } else {
                mask.push(0.0);
                tokens.push(t);
            }
        }
    }
    MlmBatch {
        tokens: IntTensor::new(vec![batch, seq], tokens).unwrap(),
        targets: IntTensor::new(vec![batch, seq], targets).unwrap(),
        mask: Tensor::new(vec![batch, seq], mask).unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (TextGen, Rng) {
        (TextGen::new(256, 1, 2), Rng::new(3).fork("mask"))
    }

    #[test]
    fn shapes_and_mask_rate() {
        let (mut g, mut r) = setup();
        let b = make_batch(&mut g, &mut r, 16, 64, 256);
        assert_eq!(b.tokens.shape(), &[16, 64]);
        assert_eq!(b.targets.shape(), &[16, 64]);
        assert_eq!(b.mask.shape(), &[16, 64]);
        let rate = b.mask.data().iter().sum::<f32>() / (16.0 * 64.0);
        // 15% of content positions; content is ~80% of tokens.
        assert!((0.06..0.20).contains(&rate), "mask rate {rate}");
    }

    #[test]
    fn masked_positions_are_corrupted_or_kept() {
        let (mut g, mut r) = setup();
        let b = make_batch(&mut g, &mut r, 8, 64, 256);
        let mut n_mask_tok = 0;
        let mut n_masked = 0;
        for i in 0..b.mask.data().len() {
            let m = b.mask.data()[i];
            let tok = b.tokens.data()[i];
            let tgt = b.targets.data()[i];
            if m == 1.0 {
                n_masked += 1;
                assert!(!vocab::is_special(tgt), "masked a special token");
                if tok == vocab::MASK {
                    n_mask_tok += 1;
                }
            } else {
                assert_eq!(tok, tgt, "unmasked position corrupted");
            }
        }
        // ~80% of masked positions replaced by [MASK]
        let frac = n_mask_tok as f64 / n_masked as f64;
        assert!((0.6..0.95).contains(&frac), "mask-token fraction {frac}");
    }

    #[test]
    fn deterministic() {
        let (mut g1, mut r1) = setup();
        let (mut g2, mut r2) = setup();
        let a = make_batch(&mut g1, &mut r1, 4, 32, 256);
        let b = make_batch(&mut g2, &mut r2, 4, 32, 256);
        assert_eq!(a.tokens.data(), b.tokens.data());
        assert_eq!(a.mask.data(), b.mask.data());
    }
}
