//! Procedural vision substrate for the ViT family (ImageNet stand-in).
//!
//! Grayscale 32x32 images: a bright foreground shape (one of 8 classes) on
//! a low-amplitude noise background. Most patches are pure background —
//! the patch analogue of delimiter tokens: the paper's ViT analysis (Fig 3,
//! Appendix A.2) shows no-op attention heads dumping probability onto
//! background patches, which is the behaviour this substrate preserves.
//!
//! Images are emitted pre-patchified to (n_patches, patch_dim) because the
//! AOT model embeds patches with a linear layer.

use crate::util::rng::Rng;
use crate::util::tensor::{IntTensor, Tensor};

pub const IMG: usize = 32;
pub const PATCH: usize = 8;
pub const N_PATCHES: usize = (IMG / PATCH) * (IMG / PATCH); // 16
pub const PATCH_DIM: usize = PATCH * PATCH; // 64 (grayscale)
pub const N_CLASSES: usize = 8;

const BG_NOISE: f32 = 0.08;
const FG_LEVEL: f32 = 0.85;

/// Shape classes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Shape {
    Square = 0,
    Circle = 1,
    Triangle = 2,
    Cross = 3,
    HStripe = 4,
    VStripe = 5,
    Diamond = 6,
    Ring = 7,
}

impl Shape {
    pub fn from_class(c: usize) -> Shape {
        match c {
            0 => Shape::Square,
            1 => Shape::Circle,
            2 => Shape::Triangle,
            3 => Shape::Cross,
            4 => Shape::HStripe,
            5 => Shape::VStripe,
            6 => Shape::Diamond,
            _ => Shape::Ring,
        }
    }
}

/// Render one image; returns (pixels[IMG*IMG], class).
pub fn render(rng: &mut Rng) -> (Vec<f32>, usize) {
    let class = rng.below(N_CLASSES as u32) as usize;
    let shape = Shape::from_class(class);
    let mut img = vec![0.0f32; IMG * IMG];
    for v in img.iter_mut() {
        *v = rng.normal().abs() * BG_NOISE;
    }
    // Random center and size, kept inside the frame.
    let r = rng.range(5, 9) as i32; // half-extent
    let cx = rng.range(r as u32 + 1, (IMG as u32) - r as u32 - 1) as i32;
    let cy = rng.range(r as u32 + 1, (IMG as u32) - r as u32 - 1) as i32;
    let level = FG_LEVEL + rng.normal() * 0.05;
    for y in 0..IMG as i32 {
        for x in 0..IMG as i32 {
            let (dx, dy) = (x - cx, y - cy);
            let inside = match shape {
                Shape::Square => dx.abs() <= r && dy.abs() <= r,
                Shape::Circle => dx * dx + dy * dy <= r * r,
                Shape::Triangle => dy >= -r && dy <= r && dx.abs() <= (dy + r) / 2,
                Shape::Cross => {
                    (dx.abs() <= r / 3 && dy.abs() <= r) || (dy.abs() <= r / 3 && dx.abs() <= r)
                }
                Shape::HStripe => dy.abs() <= r / 3,
                Shape::VStripe => dx.abs() <= r / 3,
                Shape::Diamond => dx.abs() + dy.abs() <= r,
                Shape::Ring => {
                    let d2 = dx * dx + dy * dy;
                    d2 <= r * r && d2 >= (r - 3) * (r - 3)
                }
            };
            // Stripes span the whole image, not just around the center.
            let inside = match shape {
                Shape::HStripe => (y - cy).abs() <= r / 3,
                Shape::VStripe => (x - cx).abs() <= r / 3,
                _ => inside,
            };
            if inside {
                img[(y as usize) * IMG + x as usize] = level + rng.normal() * 0.03;
            }
        }
    }
    (img, class)
}

/// Row-major patchify: (IMG, IMG) -> (N_PATCHES, PATCH_DIM).
pub fn patchify(img: &[f32]) -> Vec<f32> {
    let per_side = IMG / PATCH;
    let mut out = Vec::with_capacity(N_PATCHES * PATCH_DIM);
    for py in 0..per_side {
        for px in 0..per_side {
            for y in 0..PATCH {
                for x in 0..PATCH {
                    out.push(img[(py * PATCH + y) * IMG + px * PATCH + x]);
                }
            }
        }
    }
    out
}

pub struct VisionBatch {
    /// (B, N_PATCHES, PATCH_DIM)
    pub patches: Tensor,
    /// (B,)
    pub labels: IntTensor,
}

pub fn make_batch(rng: &mut Rng, batch: usize) -> VisionBatch {
    let mut patches = Vec::with_capacity(batch * N_PATCHES * PATCH_DIM);
    let mut labels = Vec::with_capacity(batch);
    for _ in 0..batch {
        let (img, class) = render(rng);
        patches.extend(patchify(&img));
        labels.push(class as i32);
    }
    VisionBatch {
        patches: Tensor::new(vec![batch, N_PATCHES, PATCH_DIM], patches).unwrap(),
        labels: IntTensor::new(vec![batch], labels).unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_contrast() {
        let mut rng = Rng::new(1).fork("vis");
        for _ in 0..20 {
            let (img, class) = render(&mut rng);
            assert!(class < N_CLASSES);
            let bright = img.iter().filter(|&&v| v > 0.5).count();
            // Foreground exists but most pixels are background.
            assert!(bright > 10, "shape too small: {bright}");
            assert!(bright < IMG * IMG / 2, "shape too big: {bright}");
        }
    }

    #[test]
    fn patchify_roundtrip_values() {
        // A gradient image: patch (0,0) holds the top-left 8x8 block.
        let img: Vec<f32> = (0..IMG * IMG).map(|i| i as f32).collect();
        let p = patchify(&img);
        assert_eq!(p.len(), N_PATCHES * PATCH_DIM);
        assert_eq!(p[0], 0.0);
        assert_eq!(p[1], 1.0);
        assert_eq!(p[PATCH], IMG as f32); // second row of first patch
        // second patch starts at column 8 of row 0
        assert_eq!(p[PATCH_DIM], 8.0);
    }

    #[test]
    fn all_classes_appear() {
        let mut rng = Rng::new(2).fork("vis");
        let b = make_batch(&mut rng, 256);
        assert_eq!(b.patches.shape(), &[256, N_PATCHES, PATCH_DIM]);
        let mut seen = [false; N_CLASSES];
        for &l in b.labels.data() {
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "classes seen: {seen:?}");
    }

    #[test]
    fn background_patches_dominate() {
        let mut rng = Rng::new(3).fork("vis");
        let b = make_batch(&mut rng, 32);
        let mut bg = 0;
        let total = 32 * N_PATCHES;
        for i in 0..32 {
            for p in 0..N_PATCHES {
                let start = (i * N_PATCHES + p) * PATCH_DIM;
                let slice = &b.patches.data()[start..start + PATCH_DIM];
                if slice.iter().all(|&v| v < 0.5) {
                    bg += 1;
                }
            }
        }
        let frac = bg as f64 / total as f64;
        assert!(frac > 0.4, "background patch fraction {frac}");
    }
}
