//! Causal-language-model batch assembly (OPT family, §C.2): contiguous
//! token windows with next-token targets; every position contributes to
//! the loss.

use crate::data::textgen::TextGen;
use crate::util::tensor::{IntTensor, Tensor};

pub struct ClmBatch {
    pub tokens: IntTensor,
    pub targets: IntTensor,
    pub mask: Tensor,
}

pub fn make_batch(gen: &mut TextGen, batch: usize, seq: usize) -> ClmBatch {
    let mut tokens = Vec::with_capacity(batch * seq);
    let mut targets = Vec::with_capacity(batch * seq);
    for _ in 0..batch {
        let window = gen.tokens(seq + 1);
        tokens.extend_from_slice(&window[..seq]);
        targets.extend_from_slice(&window[1..]);
    }
    ClmBatch {
        tokens: IntTensor::new(vec![batch, seq], tokens).unwrap(),
        targets: IntTensor::new(vec![batch, seq], targets).unwrap(),
        mask: Tensor::full(&[batch, seq], 1.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn targets_are_shifted_inputs() {
        let mut g = TextGen::new(256, 1, 2);
        let b = make_batch(&mut g, 4, 32);
        assert_eq!(b.tokens.shape(), &[4, 32]);
        for row in 0..4 {
            for i in 0..31 {
                assert_eq!(
                    b.tokens.data()[row * 32 + i + 1],
                    b.targets.data()[row * 32 + i],
                    "row {row} pos {i}"
                );
            }
        }
        assert!(b.mask.data().iter().all(|&m| m == 1.0));
    }

    #[test]
    fn rows_are_contiguous_stream() {
        // The generator stream continues across rows: row r+1 starts right
        // after row r's extra target token.
        let mut g1 = TextGen::new(256, 1, 7);
        let mut g2 = TextGen::new(256, 1, 7);
        let b = make_batch(&mut g1, 2, 16);
        let raw = g2.tokens(2 * 17);
        assert_eq!(&b.tokens.data()[0..16], &raw[0..16]);
        assert_eq!(&b.tokens.data()[16..32], &raw[17..33]);
    }
}
