//! The synthetic "delimiter language" corpus generator.
//!
//! Structure (designed to preserve the paper's no-op incentive — see
//! `data::mod` docs):
//!
//! * A stream of *phrases*; each phrase picks a topic and walks a
//!   topic-local bigram chain: with probability [`BIGRAM_P`] the successor
//!   is a fixed per-token mapping (learnable signal), otherwise a uniform
//!   draw from the topic (noise floor).
//! * Phrases are separated by `,` (within a sentence) or `.` + `[SEP]`
//!   (sentence end). Delimiters are frequent, appear in every sequence and
//!   are *unpredictive of* and *unpredicted by* their neighbours beyond
//!   their base rate — the model's best move around them is to do nothing,
//!   which is precisely the behaviour that manufactures outliers in vanilla
//!   softmax attention (paper §3).
//!
//! The successor mapping is derived from a seeded permutation per topic, so
//! the language itself is a deterministic function of the corpus seed.

use crate::data::vocab;
use crate::util::rng::Rng;

/// Probability the bigram chain follows the deterministic successor.
pub const BIGRAM_P: f32 = 0.8;
const PHRASE_MIN: u32 = 3;
const PHRASE_MAX: u32 = 9; // exclusive
const SENT_PHRASES_MIN: u32 = 1;
const SENT_PHRASES_MAX: u32 = 4; // exclusive

/// Infinite token stream over the delimiter language.
pub struct TextGen {
    vocab_size: usize,
    successor: Vec<i32>, // content index -> successor token id
    rng: Rng,
    buf: Vec<i32>,  // pending tokens (reversed)
    phrases_left_in_sentence: u32,
}

impl TextGen {
    /// `lang_seed` fixes the language (successor tables); `stream_seed`
    /// fixes the sampled text. Train/eval use the same language with
    /// different streams.
    pub fn new(vocab_size: usize, lang_seed: u64, stream_seed: u64) -> TextGen {
        let n = vocab::n_content(vocab_size);
        let mut lang_rng = Rng::new(lang_seed).fork("language");
        // Per-topic random cyclic successor permutation keeps chains inside
        // the topic and aperiodic enough to be interesting.
        let mut successor = vec![0i32; n];
        for topic in 0..vocab::N_TOPICS {
            let (lo, hi) = vocab::topic_range(topic, vocab_size);
            let mut ids: Vec<i32> = (lo..hi).collect();
            lang_rng.shuffle(&mut ids);
            for i in 0..ids.len() {
                let from = (ids[i] - vocab::FIRST_CONTENT) as usize;
                successor[from] = ids[(i + 1) % ids.len()];
            }
        }
        let mut rng = Rng::new(stream_seed).fork("textgen");
        let phrases = rng.range(SENT_PHRASES_MIN, SENT_PHRASES_MAX);
        TextGen {
            vocab_size,
            successor,
            rng,
            buf: Vec::new(),
            phrases_left_in_sentence: phrases,
        }
    }

    fn emit_phrase(&mut self) {
        let topic = self.rng.below(vocab::N_TOPICS as u32) as usize;
        let (lo, hi) = vocab::topic_range(topic, self.vocab_size);
        let len = self.rng.range(PHRASE_MIN, PHRASE_MAX);
        let mut tok = self.rng.range(lo as u32, hi as u32) as i32;
        let mut phrase = Vec::with_capacity(len as usize + 1);
        for _ in 0..len {
            phrase.push(tok);
            tok = if self.rng.bernoulli(BIGRAM_P) {
                self.successor[(tok - vocab::FIRST_CONTENT) as usize]
            } else {
                self.rng.range(lo as u32, hi as u32) as i32
            };
        }
        // Delimiter: end of sentence -> ". [SEP]", else ",".
        self.phrases_left_in_sentence -= 1;
        if self.phrases_left_in_sentence == 0 {
            phrase.push(vocab::PERIOD);
            phrase.push(vocab::SEP);
            self.phrases_left_in_sentence = self.rng.range(SENT_PHRASES_MIN, SENT_PHRASES_MAX);
        } else {
            phrase.push(vocab::COMMA);
        }
        // buf is a stack: push reversed.
        for &t in phrase.iter().rev() {
            self.buf.push(t);
        }
    }

    pub fn next_token(&mut self) -> i32 {
        loop {
            if let Some(t) = self.buf.pop() {
                return t;
            }
            self.emit_phrase();
        }
    }

    /// Fill a sequence of length `t`, starting with [CLS] (encoder style).
    pub fn sequence_with_cls(&mut self, t: usize) -> Vec<i32> {
        let mut seq = Vec::with_capacity(t);
        seq.push(vocab::CLS);
        while seq.len() < t {
            seq.push(self.next_token());
        }
        seq
    }

    /// Raw continuous tokens (decoder style).
    pub fn tokens(&mut self, n: usize) -> Vec<i32> {
        (0..n).map(|_| self.next_token()).collect()
    }

    pub fn successor_of(&self, tok: i32) -> i32 {
        self.successor[(tok - vocab::FIRST_CONTENT) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = TextGen::new(256, 1, 2);
        let mut b = TextGen::new(256, 1, 2);
        assert_eq!(a.tokens(500), b.tokens(500));
    }

    #[test]
    fn different_streams_same_language() {
        let mut a = TextGen::new(256, 1, 2);
        let mut b = TextGen::new(256, 1, 3);
        assert_ne!(a.tokens(200), b.tokens(200));
        // same language: successor tables agree
        assert_eq!(
            a.successor_of(vocab::FIRST_CONTENT),
            b.successor_of(vocab::FIRST_CONTENT)
        );
        let c = TextGen::new(256, 9, 3);
        // different language seed: (very likely) different successors
        let diff = (vocab::FIRST_CONTENT..40)
            .any(|t| a.successor_of(t) != c.successor_of(t));
        assert!(diff);
    }

    #[test]
    fn contains_delimiters_and_valid_tokens() {
        let mut g = TextGen::new(256, 1, 2);
        let toks = g.tokens(2000);
        assert!(toks.iter().any(|&t| t == vocab::SEP));
        assert!(toks.iter().any(|&t| t == vocab::COMMA));
        assert!(toks.iter().any(|&t| t == vocab::PERIOD));
        for &t in &toks {
            assert!((0..256).contains(&t));
            assert_ne!(t, vocab::PAD);
            assert_ne!(t, vocab::MASK);
            assert_ne!(t, vocab::CLS);
        }
        // Delimiter rate: one per ~3-9 content tokens plus sentence ends.
        let delim = toks.iter().filter(|&&t| vocab::is_delimiter(t)).count();
        let rate = delim as f64 / toks.len() as f64;
        assert!((0.08..0.40).contains(&rate), "delimiter rate {rate}");
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // Empirical successor-follow rate should be near BIGRAM_P.
        let mut g = TextGen::new(256, 1, 2);
        let toks = g.tokens(20000);
        let (mut follows, mut content_pairs) = (0usize, 0usize);
        for w in toks.windows(2) {
            if !vocab::is_special(w[0])
                && !vocab::is_special(w[1])
                && vocab::topic_of(w[0], 256) == vocab::topic_of(w[1], 256)
            {
                content_pairs += 1;
                if g.successor_of(w[0]) == w[1] {
                    follows += 1;
                }
            }
        }
        let rate = follows as f64 / content_pairs as f64;
        assert!(
            (BIGRAM_P as f64 - 0.1..=BIGRAM_P as f64 + 0.1).contains(&rate),
            "bigram follow rate {rate}"
        );
    }

    #[test]
    fn cls_sequences() {
        let mut g = TextGen::new(256, 1, 2);
        let s = g.sequence_with_cls(64);
        assert_eq!(s.len(), 64);
        assert_eq!(s[0], vocab::CLS);
        assert!(s[1..].iter().all(|&t| t != vocab::CLS));
    }
}
