//! Host-side weight PTQ: symmetric per-tensor fake-quantization of the
//! model parameters (§5 "uniform affine quantization — symmetric weights"),
//! with min-max or MSE range estimation (§C.4: min-max everywhere except
//! OPT, where MSE performs better; MSE recommended for <8 bits, App. B.7).

use crate::quant::estimators::EstimatorKind;
use crate::quant::grid::QParams;
use crate::util::stats;
use crate::util::tensor::Tensor;

/// Pick symmetric quantizer params for one weight tensor.
pub fn weight_qparams(w: &[f32], kind: EstimatorKind, bits: u32) -> QParams {
    match kind {
        EstimatorKind::Mse => {
            let absmax = stats::inf_norm(w);
            let mut best = QParams::symmetric(absmax, bits);
            let mut best_err = best.sq_error(w);
            for i in 1..=40 {
                let alpha = 1.0 - i as f32 * 0.975 / 40.0;
                let q = QParams::symmetric(absmax * alpha, bits);
                let e = q.sq_error(w);
                if e < best_err {
                    best_err = e;
                    best = q;
                }
            }
            best
        }
        // Range estimators other than MSE degenerate to min-max for
        // weights (they are static tensors — no batch dimension to stream).
        _ => QParams::symmetric(stats::inf_norm(w), bits),
    }
}

/// Fake-quantize one weight tensor.
pub fn fake_quant_weight(w: &Tensor, kind: EstimatorKind, bits: u32) -> Tensor {
    let q = weight_qparams(w.data(), kind, bits);
    let mut out = w.clone();
    q.fq_slice(out.data_mut());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, gen};
    use crate::util::rng::Rng;

    #[test]
    fn minmax_weight_error_small_for_smooth_weights() {
        let mut rng = Rng::new(1);
        let w = Tensor::from_fn(&[64, 64], |_| rng.normal() * 0.02);
        let wq = fake_quant_weight(&w, EstimatorKind::MinMax, 8);
        let max_err = w
            .data()
            .iter()
            .zip(wq.data())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        let scale = weight_qparams(w.data(), EstimatorKind::MinMax, 8).scale;
        assert!(max_err <= scale * 0.5 + 1e-7, "err {max_err} scale {scale}");
    }

    #[test]
    fn mse_no_worse_than_minmax() {
        let mut rng = Rng::new(2);
        let mut data: Vec<f32> = (0..4096).map(|_| rng.normal() * 0.02).collect();
        data[0] = 1.5; // outlier weight
        let q_mm = weight_qparams(&data, EstimatorKind::MinMax, 4);
        let q_mse = weight_qparams(&data, EstimatorKind::Mse, 4);
        assert!(q_mse.sq_error(&data) <= q_mm.sq_error(&data) + 1e-9);
    }

    #[test]
    fn prop_symmetry_preserved() {
        check(
            "weight_fq_symmetric",
            |rng| gen::outlier_vec(rng, 128),
            |v| {
                let q = weight_qparams(v, EstimatorKind::MinMax, 8);
                for &x in v.iter().take(16) {
                    if (q.fq(x) + q.fq(-x)).abs() > 1e-4 {
                        return Err(format!("asymmetric at {x}"));
                    }
                }
                Ok(())
            },
        );
    }
}
