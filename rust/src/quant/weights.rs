//! Host-side weight PTQ: symmetric per-tensor fake-quantization of the
//! model parameters (§5 "uniform affine quantization — symmetric weights"),
//! with min-max or MSE range estimation (§C.4: min-max everywhere except
//! OPT, where MSE performs better; MSE recommended for <8 bits, App. B.7).

use crate::quant::estimators::EstimatorKind;
use crate::quant::grid::QParams;
use crate::util::stats;
use crate::util::tensor::Tensor;

/// Pick symmetric quantizer params for one weight tensor.
pub fn weight_qparams(w: &[f32], kind: EstimatorKind, bits: u32) -> QParams {
    match kind {
        EstimatorKind::Mse => {
            let absmax = stats::inf_norm(w);
            let mut best = QParams::symmetric(absmax, bits);
            let mut best_err = best.sq_error(w);
            for i in 1..=40 {
                let alpha = 1.0 - i as f32 * 0.975 / 40.0;
                let q = QParams::symmetric(absmax * alpha, bits);
                let e = q.sq_error(w);
                if e < best_err {
                    best_err = e;
                    best = q;
                }
            }
            best
        }
        // Range estimators other than MSE degenerate to min-max for
        // weights (they are static tensors — no batch dimension to stream).
        _ => QParams::symmetric(stats::inf_norm(w), bits),
    }
}

/// Fake-quantize one weight tensor.
pub fn fake_quant_weight(w: &Tensor, kind: EstimatorKind, bits: u32) -> Tensor {
    let q = weight_qparams(w.data(), kind, bits);
    let mut out = w.clone();
    q.fq_slice(out.data_mut());
    out
}

/// A weight tensor held as real `i8` integers plus its per-tensor scale —
/// the storage format of the native INT8 inference backend
/// ([`crate::infer`]).
///
/// The symmetric grid of [`QParams::symmetric`] places the zero point at
/// mid-grid (128 for 8 bits), so the stored integer is `q − 128 ∈
/// [−128, 127]` and dequantization is just `scale · int`. By construction
/// `scale * data[i]` equals [`fake_quant_weight`] element-for-element (the
/// invariant `int8_matches_fake_quant` asserts): the integer backend and the
/// in-graph fake-quant path consume the *same* weight grid.
#[derive(Debug, Clone)]
pub struct Int8Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<i8>,
    pub scale: f32,
}

impl Int8Tensor {
    /// Dequantize one element (`scale · int`).
    pub fn dequant(&self, i: usize) -> f32 {
        self.scale * self.data[i] as f32
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Quantize one weight tensor to real INT8 storage (symmetric per-tensor,
/// §5 "uniform affine quantization — symmetric weights", 8 bits).
pub fn quantize_weight_int8(w: &Tensor, kind: EstimatorKind) -> Int8Tensor {
    let q = weight_qparams(w.data(), kind, 8);
    // q.zero_point is mid-grid (128): code ∈ [0, 255] → int ∈ [−128, 127].
    let data = w.data().iter().map(|&x| (q.code(x) - q.zero_point) as i8).collect();
    Int8Tensor { shape: w.shape().to_vec(), data, scale: q.scale }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, gen};
    use crate::util::rng::Rng;

    #[test]
    fn minmax_weight_error_small_for_smooth_weights() {
        let mut rng = Rng::new(1);
        let w = Tensor::from_fn(&[64, 64], |_| rng.normal() * 0.02);
        let wq = fake_quant_weight(&w, EstimatorKind::MinMax, 8);
        let max_err = w
            .data()
            .iter()
            .zip(wq.data())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        let scale = weight_qparams(w.data(), EstimatorKind::MinMax, 8).scale;
        assert!(max_err <= scale * 0.5 + 1e-7, "err {max_err} scale {scale}");
    }

    #[test]
    fn mse_no_worse_than_minmax() {
        let mut rng = Rng::new(2);
        let mut data: Vec<f32> = (0..4096).map(|_| rng.normal() * 0.02).collect();
        data[0] = 1.5; // outlier weight
        let q_mm = weight_qparams(&data, EstimatorKind::MinMax, 4);
        let q_mse = weight_qparams(&data, EstimatorKind::Mse, 4);
        assert!(q_mse.sq_error(&data) <= q_mm.sq_error(&data) + 1e-9);
    }

    /// The integer storage and the fake-quant simulation sit on the same
    /// grid: `scale * i8` must reproduce `fake_quant_weight` exactly.
    #[test]
    fn int8_matches_fake_quant() {
        let mut rng = Rng::new(3);
        let mut data: Vec<f32> = (0..2048).map(|_| rng.normal() * 0.05).collect();
        data[17] = 0.9; // outlier exercises the clip at the grid edge
        data[18] = -1.2;
        for kind in [EstimatorKind::MinMax, EstimatorKind::Mse] {
            let w = Tensor::new(vec![2048], data.clone()).unwrap();
            let fq = fake_quant_weight(&w, kind, 8);
            let i8t = quantize_weight_int8(&w, kind);
            assert_eq!(i8t.shape, vec![2048]);
            for i in 0..data.len() {
                assert_eq!(
                    i8t.dequant(i),
                    fq.data()[i],
                    "grid mismatch at {i}: int {} scale {}",
                    i8t.data[i],
                    i8t.scale
                );
            }
        }
    }

    #[test]
    fn prop_symmetry_preserved() {
        check(
            "weight_fq_symmetric",
            |rng| gen::outlier_vec(rng, 128),
            |v| {
                let q = weight_qparams(v, EstimatorKind::MinMax, 8);
                for &x in v.iter().take(16) {
                    if (q.fq(x) + q.fq(-x)).abs() > 1e-4 {
                        return Err(format!("asymmetric at {x}"));
                    }
                }
                Ok(())
            },
        );
    }
}
