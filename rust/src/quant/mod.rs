//! Post-training quantization (paper §2 eq. 1 and §C.4).
//!
//! * [`grid`] — the uniform affine quantizer: scale / zero-point / qmax
//!   parameterization shared with the in-graph Pallas fake-quant kernel.
//! * [`estimators`] — activation/weight range estimation: min-max, running
//!   min-max with momentum, percentile (99.99 / 99.999), and MSE grid
//!   search; these are the §C.4 "range estimation" configurations the paper
//!   selects between.
//! * [`weights`] — host-side symmetric weight PTQ in two output formats:
//!   fake-quantized f32 (fed to the `eval_quant`/`serve_score` simulation
//!   — the paper's symmetric weight PTQ at any bitwidth, Table 10) and
//!   real [`weights::Int8Tensor`] storage on the *same* grid, consumed by
//!   the native integer backend ([`crate::infer`]).

pub mod estimators;
pub mod grid;
pub mod weights;

pub use estimators::{Calibration, EstimatorKind};
pub use grid::QParams;
pub use weights::Int8Tensor;
