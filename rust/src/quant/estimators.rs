//! Activation range estimation (§C.4 "Quantization settings").
//!
//! The paper's static-range activation PTQ estimates per-tensor quantization
//! parameters from ~16 calibration batches. Four estimators are
//! implemented:
//!
//! * `MinMax` — global min/max over all calibration data.
//! * `RunningMinMax { momentum }` — exponential moving average of per-batch
//!   min/max (momentum 0.9 over 16 batches in the paper).
//! * `Percentile(p)` — p / (100−p) two-sided percentiles (99.99 / 99.999 in
//!   §C.4; "in almost all cases 99.999 gives the lowest W8A8 perplexity").
//! * `Mse` — grid search over symmetric shrinkings of the min-max range,
//!   minimizing squared reconstruction error (recommended for low-bit,
//!   Appendix B.7).
//!
//! Percentile/MSE need sample values, not just extrema: each point keeps a
//! bounded reservoir sample (uniform over everything observed) plus exact
//! extrema, so memory stays flat regardless of calibration size.

use crate::quant::grid::QParams;
use crate::util::rng::Rng;
use crate::util::stats;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EstimatorKind {
    MinMax,
    RunningMinMax { momentum: f32 },
    Percentile { pct: f64 },
    Mse,
}

impl EstimatorKind {
    pub fn parse(s: &str) -> anyhow::Result<EstimatorKind> {
        Ok(match s {
            "minmax" => EstimatorKind::MinMax,
            "running" => EstimatorKind::RunningMinMax { momentum: 0.9 },
            "p9999" => EstimatorKind::Percentile { pct: 99.99 },
            "p99999" => EstimatorKind::Percentile { pct: 99.999 },
            "mse" => EstimatorKind::Mse,
            other => anyhow::bail!(
                "unknown estimator {other:?} (minmax|running|p9999|p99999|mse)"
            ),
        })
    }

    /// Round-trippable name (`parse(name())` is identity for the standard
    /// configurations).
    pub fn name(&self) -> String {
        match self {
            EstimatorKind::MinMax => "minmax".into(),
            EstimatorKind::RunningMinMax { .. } => "running".into(),
            EstimatorKind::Percentile { pct } => format!("p{}", pct.to_string().replace('.', "")),
            EstimatorKind::Mse => "mse".into(),
        }
    }
}

const RESERVOIR_CAP: usize = 1 << 15;

/// Streaming per-point statistics.
#[derive(Debug, Clone)]
struct PointAccum {
    global_min: f32,
    global_max: f32,
    run_min: f32,
    run_max: f32,
    batches: usize,
    reservoir: Vec<f32>,
    seen: u64,
    rng: Rng,
}

impl PointAccum {
    fn new(seed: u64) -> PointAccum {
        PointAccum {
            global_min: f32::INFINITY,
            global_max: f32::NEG_INFINITY,
            run_min: 0.0,
            run_max: 0.0,
            batches: 0,
            reservoir: Vec::new(),
            seen: 0,
            rng: Rng::new(seed).fork("reservoir"),
        }
    }

    fn observe(&mut self, data: &[f32], momentum: f32) {
        if data.is_empty() {
            return;
        }
        let mut bmin = f32::INFINITY;
        let mut bmax = f32::NEG_INFINITY;
        for &x in data {
            bmin = bmin.min(x);
            bmax = bmax.max(x);
        }
        self.global_min = self.global_min.min(bmin);
        self.global_max = self.global_max.max(bmax);
        if self.batches == 0 {
            self.run_min = bmin;
            self.run_max = bmax;
        } else {
            self.run_min = momentum * self.run_min + (1.0 - momentum) * bmin;
            self.run_max = momentum * self.run_max + (1.0 - momentum) * bmax;
        }
        self.batches += 1;
        // Algorithm R reservoir sampling.
        for &x in data {
            self.seen += 1;
            if self.reservoir.len() < RESERVOIR_CAP {
                self.reservoir.push(x);
            } else {
                let j = (self.rng.next_u64() % self.seen) as usize;
                if j < RESERVOIR_CAP {
                    self.reservoir[j] = x;
                }
            }
        }
    }

    fn finalize(&self, kind: EstimatorKind, bits: u32) -> QParams {
        match kind {
            EstimatorKind::MinMax => QParams::asymmetric(self.global_min, self.global_max, bits),
            EstimatorKind::RunningMinMax { .. } => {
                QParams::asymmetric(self.run_min, self.run_max, bits)
            }
            EstimatorKind::Percentile { pct } => {
                let mut v = self.reservoir.clone();
                if v.is_empty() {
                    return QParams::asymmetric(0.0, 1.0, bits);
                }
                v.sort_by(|a, b| a.total_cmp(b));
                let hi = stats::percentile_sorted(&v, pct);
                let lo = stats::percentile_sorted(&v, 100.0 - pct);
                QParams::asymmetric(lo, hi, bits)
            }
            EstimatorKind::Mse => mse_search(
                &self.reservoir,
                self.global_min,
                self.global_max,
                bits,
            ),
        }
    }
}

/// Grid search over shrink factors of the min-max range (keeps the range
/// anchored at zero-crossing like the asymmetric grid itself).
pub fn mse_search(sample: &[f32], min: f32, max: f32, bits: u32) -> QParams {
    if sample.is_empty() {
        return QParams::asymmetric(min, max, bits);
    }
    let mut best = QParams::asymmetric(min, max, bits);
    let mut best_err = best.sq_error(sample);
    for i in 1..=40 {
        let alpha = 1.0 - i as f32 * 0.975 / 40.0; // 1.0 down to 0.025
        let q = QParams::asymmetric(min * alpha, max * alpha, bits);
        let e = q.sq_error(sample);
        if e < best_err {
            best_err = e;
            best = q;
        }
    }
    best
}

/// Calibration over the manifest's ordered quant-point list.
pub struct Calibration {
    kind: EstimatorKind,
    points: Vec<PointAccum>,
}

impl Calibration {
    pub fn new(kind: EstimatorKind, n_points: usize, seed: u64) -> Calibration {
        Calibration {
            kind,
            points: (0..n_points)
                .map(|i| PointAccum::new(seed ^ ((i as u64) << 20)))
                .collect(),
        }
    }

    pub fn observe(&mut self, point: usize, data: &[f32]) {
        let momentum = match self.kind {
            EstimatorKind::RunningMinMax { momentum } => momentum,
            _ => 0.9,
        };
        self.points[point].observe(data, momentum);
    }

    pub fn n_points(&self) -> usize {
        self.points.len()
    }

    /// Per-point quantizer parameters at the given activation bitwidth.
    pub fn finalize(&self, bits: u32) -> Vec<QParams> {
        self.points.iter().map(|p| p.finalize(self.kind, bits)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, gen};

    fn observe_all(kind: EstimatorKind, batches: &[Vec<f32>]) -> PointAccum {
        let mut c = Calibration::new(kind, 1, 7);
        for b in batches {
            c.observe(0, b);
        }
        c.points[0].clone()
    }

    #[test]
    fn minmax_covers_everything() {
        let acc = observe_all(
            EstimatorKind::MinMax,
            &[vec![-1.0, 2.0], vec![0.5, 10.0]],
        );
        let q = acc.finalize(EstimatorKind::MinMax, 8);
        let (lo, hi) = q.range();
        assert!(lo <= -0.99 && hi >= 9.9, "({lo},{hi})");
    }

    #[test]
    fn running_minmax_smooths_spikes() {
        // 15 calm batches then one spike: running range ≪ global range.
        let mut batches: Vec<Vec<f32>> = (0..15).map(|_| vec![-1.0, 1.0]).collect();
        batches.insert(7, vec![-1.0, 100.0]);
        let acc = observe_all(EstimatorKind::RunningMinMax { momentum: 0.9 }, &batches);
        assert!(acc.run_max < 20.0, "run_max={}", acc.run_max);
        assert_eq!(acc.global_max, 100.0);
    }

    #[test]
    fn percentile_ignores_rare_outliers() {
        let mut data = vec![0.0f32; 100_000];
        let mut rng = Rng::new(1);
        for v in data.iter_mut() {
            *v = rng.normal();
        }
        data[0] = 1000.0;
        let c = {
            let mut c = Calibration::new(EstimatorKind::Percentile { pct: 99.99 }, 1, 3);
            c.observe(0, &data);
            c
        };
        let q = &c.finalize(8)[0];
        let (_, hi) = q.range();
        assert!(hi < 50.0, "hi={hi}");
    }

    #[test]
    fn mse_beats_minmax_on_outlier_data_at_low_bits() {
        // At 4 bits an outlier wrecks the min-max grid's resolution for the
        // bulk; clipping it is SSE-optimal (the Appendix B.7 low-bit story).
        let mut data: Vec<f32> = Vec::new();
        let mut rng = Rng::new(2);
        for _ in 0..100_000 {
            data.push(rng.normal());
        }
        data.push(50.0);
        let mn = data.iter().cloned().fold(f32::INFINITY, f32::min);
        let mx = data.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let q_minmax = QParams::asymmetric(mn, mx, 4);
        let q_mse = mse_search(&data, mn, mx, 4);
        assert!(
            q_mse.sq_error(&data) < q_minmax.sq_error(&data) * 0.5,
            "mse {} vs minmax {}",
            q_mse.sq_error(&data),
            q_minmax.sq_error(&data)
        );
        // The MSE grid must actually clip the outlier's tail.
        let (_, hi) = q_mse.range();
        assert!(hi < 45.0, "hi={hi}");
    }

    #[test]
    fn reservoir_stays_bounded() {
        let mut c = Calibration::new(EstimatorKind::Mse, 1, 5);
        let chunk: Vec<f32> = (0..10_000).map(|i| i as f32).collect();
        for _ in 0..10 {
            c.observe(0, &chunk);
        }
        assert!(c.points[0].reservoir.len() <= RESERVOIR_CAP);
        assert_eq!(c.points[0].seen, 100_000);
        assert_eq!(c.points[0].global_max, 9999.0);
    }

    #[test]
    fn parse_names() {
        for s in ["minmax", "running", "p9999", "p99999", "mse"] {
            assert!(EstimatorKind::parse(s).is_ok(), "{s}");
        }
        assert!(EstimatorKind::parse("bogus").is_err());
    }

    #[test]
    fn prop_estimator_range_contains_bulk() {
        check(
            "range_contains_bulk",
            |rng| gen::f32_vec(rng, 256, 2.0),
            |v| {
                let mut c = Calibration::new(EstimatorKind::MinMax, 1, 1);
                c.observe(0, v);
                let q = &c.finalize(8)[0];
                let (lo, hi) = q.range();
                // zero-point rounding may shift the window by up to one
                // step; the grid still covers the data to within `scale`.
                for &x in v {
                    if x < lo - q.scale || x > hi + q.scale {
                        return Err(format!("{x} outside [{lo},{hi}] (scale {})", q.scale));
                    }
                }
                Ok(())
            },
        );
    }
}
