//! The uniform affine quantization grid — eq. (1) of the paper:
//!
//! ```text
//! x̂ = q(x; s, z, b) = s · (clip(⌊x/s⌉ + z, 0, 2^b − 1) − z)
//! ```
//!
//! The same (scale, zero_point, qmax) triple parameterizes both the host
//! weight fake-quant here and the in-graph Pallas fake-quant kernel (the
//! `eval_quant` program takes them as runtime inputs), so rust-estimated
//! ranges transfer exactly.

/// Per-tensor quantizer parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QParams {
    pub scale: f32,
    pub zero_point: f32,
    /// 2^bits − 1.
    pub qmax: f32,
}

pub fn qmax_for_bits(bits: u32) -> f32 {
    ((1u64 << bits) - 1) as f32
}

impl QParams {
    /// Asymmetric quantizer covering [min, max] (activations, §5). The
    /// range is widened to include 0 so that zero quantizes exactly
    /// (standard practice; padding/ReLU zeros stay exact).
    pub fn asymmetric(min: f32, max: f32, bits: u32) -> QParams {
        let qmax = qmax_for_bits(bits);
        let lo = min.min(0.0);
        let hi = max.max(0.0);
        let range = (hi - lo).max(1e-12);
        let scale = range / qmax;
        let zero_point = (-lo / scale).round_ties_even().clamp(0.0, qmax);
        QParams { scale, zero_point, qmax }
    }

    /// Symmetric quantizer covering [-absmax, absmax] (weights, §5): the
    /// zero point sits mid-grid.
    pub fn symmetric(absmax: f32, bits: u32) -> QParams {
        let qmax = qmax_for_bits(bits);
        let half = ((1u64 << (bits - 1)) - 1) as f32; // e.g. 127 for 8 bits
        let scale = absmax.max(1e-12) / half;
        QParams { scale, zero_point: half + 1.0, qmax }
    }

    /// Grid code of `x` — eq. 1's `clip(⌊x/s⌉ + z, 0, 2^b − 1)` with
    /// round-to-nearest-even (like jnp.round in the kernel), returned as
    /// an integral f32. **The single implementation of the rounding
    /// rule**: the fake-quant simulation ([`QParams::fq`]) and the native
    /// integer backend's u8/i8 extraction ([`crate::infer`],
    /// [`crate::quant::weights`]) all go through here, so they cannot
    /// drift apart.
    pub fn code(&self, x: f32) -> f32 {
        ((x / self.scale).round_ties_even() + self.zero_point).clamp(0.0, self.qmax)
    }

    /// Fake-quantize a single value (eq. 1).
    pub fn fq(&self, x: f32) -> f32 {
        self.scale * (self.code(x) - self.zero_point)
    }

    /// Fake-quantize a slice in place.
    pub fn fq_slice(&self, xs: &mut [f32]) {
        for x in xs.iter_mut() {
            *x = self.fq(*x);
        }
    }

    /// Representable range [lo, hi] of the grid.
    pub fn range(&self) -> (f32, f32) {
        (
            self.scale * (0.0 - self.zero_point),
            self.scale * (self.qmax - self.zero_point),
        )
    }

    /// Sum of squared quantization errors on a sample (the MSE-estimator
    /// objective, §C.4 / Appendix B.7).
    pub fn sq_error(&self, xs: &[f32]) -> f64 {
        xs.iter()
            .map(|&x| {
                let e = (x - self.fq(x)) as f64;
                e * e
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, gen};

    #[test]
    fn qmax_values() {
        assert_eq!(qmax_for_bits(8), 255.0);
        assert_eq!(qmax_for_bits(6), 63.0);
        assert_eq!(qmax_for_bits(4), 15.0);
    }

    #[test]
    fn asymmetric_covers_range() {
        let q = QParams::asymmetric(-1.0, 3.0, 8);
        let (lo, hi) = q.range();
        assert!(lo <= -0.99 && hi >= 2.99, "range ({lo},{hi})");
        // in-range values round-trip within one step
        for &x in &[-1.0f32, 0.0, 0.5, 2.9] {
            assert!((q.fq(x) - x).abs() <= q.scale, "x={x}");
        }
        // out-of-range clips
        assert!(q.fq(100.0) <= hi + 1e-6);
        assert!(q.fq(-100.0) >= lo - 1e-6);
    }

    #[test]
    fn zero_is_exact() {
        for (mn, mx) in [(-1.0, 3.0), (0.1, 2.0), (-5.0, -0.2)] {
            let q = QParams::asymmetric(mn, mx, 8);
            assert_eq!(q.fq(0.0), 0.0, "({mn},{mx})");
        }
    }

    #[test]
    fn symmetric_is_symmetric() {
        let q = QParams::symmetric(2.0, 8);
        for &x in &[0.25f32, 0.8, 1.5, 1.99] {
            assert!((q.fq(x) + q.fq(-x)).abs() < 1e-6, "x={x}");
        }
        assert_eq!(q.fq(0.0), 0.0);
        assert!((q.fq(2.0) - 2.0).abs() <= q.scale);
    }

    #[test]
    fn more_bits_less_error() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32 / 500.0 - 1.0) * 3.0).collect();
        let e8 = QParams::asymmetric(-3.0, 3.0, 8).sq_error(&xs);
        let e6 = QParams::asymmetric(-3.0, 3.0, 6).sq_error(&xs);
        let e4 = QParams::asymmetric(-3.0, 3.0, 4).sq_error(&xs);
        assert!(e8 < e6 && e6 < e4, "e8={e8} e6={e6} e4={e4}");
    }

    #[test]
    fn prop_fq_idempotent() {
        check(
            "fq_idempotent",
            |rng| {
                let v = gen::outlier_vec(rng, 64);
                let bits = *rng.choice(&[4u32, 6, 8]);
                (v, bits)
            },
            |(v, bits)| {
                let mn = v.iter().cloned().fold(f32::INFINITY, f32::min);
                let mx = v.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let q = QParams::asymmetric(mn, mx, *bits);
                for &x in v {
                    let once = q.fq(x);
                    let twice = q.fq(once);
                    if (once - twice).abs() > 1e-5 {
                        return Err(format!("not idempotent at {x}: {once} vs {twice}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_fq_bounded_by_grid() {
        check(
            "fq_bounded",
            |rng| gen::outlier_vec(rng, 128),
            |v| {
                let q = QParams::symmetric(crate::util::stats::inf_norm(v), 8);
                let (lo, hi) = q.range();
                for &x in v {
                    let y = q.fq(x);
                    if y < lo - 1e-5 || y > hi + 1e-5 {
                        return Err(format!("fq({x})={y} outside [{lo},{hi}]"));
                    }
                }
                Ok(())
            },
        );
    }
}
