//! Attention-pattern analysis (paper §3 Figs 2-3, §5.5 Fig 8): where does
//! each head put its probability mass, how large are the values there, and
//! does the head implement a (soft) no-op?

use crate::data::vocab;
use crate::util::tensor::Tensor;

/// Per-head summary over one batch of probabilities (B, H, T, T), values
/// (B, H, T, Dh) and optional gates (B, H, T).
#[derive(Debug, Clone)]
pub struct HeadSummary {
    pub head: usize,
    /// Mean probability mass assigned to delimiter key positions.
    pub delim_mass: f64,
    /// Mean ‖v‖ over delimiter key positions vs all positions.
    pub delim_value_norm: f64,
    pub mean_value_norm: f64,
    /// Mean ‖p·v‖ (the per-token update magnitude) — small ⇒ no-op.
    pub update_norm: f64,
    /// Fraction of probability entries that are exactly zero (clipped
    /// softmax can produce exact zeros; vanilla cannot).
    pub exact_zero_frac: f64,
    /// Mean gate probability (gated attention only).
    pub mean_gate: Option<f64>,
}

/// Summarize attention behaviour for every head of one layer.
///
/// `tokens` is the (B*T) token batch used to locate delimiter key
/// positions; for ViT pass `None` and `bg_keys` marks background patches.
pub fn summarize_heads(
    probs: &Tensor,
    values: &Tensor,
    gates: Option<&Tensor>,
    tokens: Option<&[i32]>,
    bg_keys: Option<&[bool]>,
) -> Vec<HeadSummary> {
    let [b, h, t, t2]: [usize; 4] = probs.shape().try_into().expect("probs rank 4");
    assert_eq!(t, t2);
    let dh = values.shape()[3];
    let mut out = Vec::with_capacity(h);
    for head in 0..h {
        let mut delim_mass = 0.0f64;
        let mut rows = 0.0f64;
        let mut zero = 0u64;
        let mut entries = 0u64;
        let mut delim_vnorm = 0.0f64;
        let mut delim_n = 0.0f64;
        let mut all_vnorm = 0.0f64;
        let mut upd_norm = 0.0f64;
        for bi in 0..b {
            // per-key delimiter flags for this sequence
            let is_delim = |ti: usize| -> bool {
                if let Some(toks) = tokens {
                    vocab::is_delimiter(toks[bi * t + ti])
                } else if let Some(bg) = bg_keys {
                    bg[bi * t + ti]
                } else {
                    false
                }
            };
            // value norms
            for ti in 0..t {
                let mut n2 = 0.0f64;
                for di in 0..dh {
                    let v = values.at(&[bi, head, ti, di]) as f64;
                    n2 += v * v;
                }
                let n = n2.sqrt();
                all_vnorm += n;
                if is_delim(ti) {
                    delim_vnorm += n;
                    delim_n += 1.0;
                }
            }
            // probability mass + update norms
            for qi in 0..t {
                let mut mass = 0.0f64;
                let mut upd = vec![0.0f64; dh];
                for ki in 0..t {
                    let p = probs.at(&[bi, head, qi, ki]) as f64;
                    entries += 1;
                    if p == 0.0 {
                        zero += 1;
                    }
                    if is_delim(ki) {
                        mass += p;
                    }
                    for di in 0..dh {
                        upd[di] += p * values.at(&[bi, head, ki, di]) as f64;
                    }
                }
                delim_mass += mass;
                rows += 1.0;
                upd_norm += upd.iter().map(|x| x * x).sum::<f64>().sqrt();
            }
        }
        let mean_gate = gates.map(|g| {
            let mut s = 0.0f64;
            for bi in 0..b {
                for ti in 0..t {
                    s += g.at(&[bi, head, ti]) as f64;
                }
            }
            s / (b * t) as f64
        });
        out.push(HeadSummary {
            head,
            delim_mass: delim_mass / rows,
            delim_value_norm: if delim_n > 0.0 { delim_vnorm / delim_n } else { 0.0 },
            mean_value_norm: all_vnorm / (b * t) as f64,
            update_norm: upd_norm / rows,
            exact_zero_frac: zero as f64 / entries as f64,
            mean_gate,
        });
    }
    out
}

/// ASCII heatmap of one head's (T, T) probability matrix (analysis dumps;
/// the textual stand-in for the paper's figure panels).
pub fn ascii_heatmap(probs: &Tensor, batch: usize, head: usize, max_rows: usize) -> String {
    let shades = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let t = probs.shape()[2];
    let rows = t.min(max_rows);
    let mut out = String::new();
    for qi in 0..rows {
        for ki in 0..t.min(120) {
            let p = probs.at(&[batch, head, qi, ki]);
            let idx = ((p * (shades.len() - 1) as f32).round() as usize).min(shades.len() - 1);
            out.push(shades[idx]);
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a no-op head: all probability on key 0 (a delimiter), values
    /// at key 0 near zero.
    fn noop_setup() -> (Tensor, Tensor, Vec<i32>) {
        let (b, h, t, dh) = (1, 2, 4, 3);
        let mut probs = Tensor::zeros(&[b, h, t, t]);
        let mut values = Tensor::from_fn(&[b, h, t, dh], |_| 1.0);
        for qi in 0..t {
            probs.set(&[0, 0, qi, 0], 1.0); // head 0: everything on key 0
            for ki in 0..t {
                probs.set(&[0, 1, qi, ki], 0.25); // head 1: uniform
            }
        }
        for di in 0..dh {
            values.set(&[0, 0, 0, di], 0.01); // tiny value at delimiter
        }
        let tokens = vec![vocab::SEP, 10, 11, 12];
        (probs, values, tokens)
    }

    #[test]
    fn noop_head_detected() {
        let (probs, values, tokens) = noop_setup();
        let s = summarize_heads(&probs, &values, None, Some(&tokens), None);
        assert!(s[0].delim_mass > 0.99);
        assert!(s[1].delim_mass < 0.3);
        assert!(s[0].update_norm < 0.05, "head0 update {}", s[0].update_norm);
        assert!(s[1].update_norm > 0.5, "head1 update {}", s[1].update_norm);
        assert!(s[0].delim_value_norm < s[0].mean_value_norm);
    }

    #[test]
    fn exact_zero_fraction() {
        let (probs, values, tokens) = noop_setup();
        let s = summarize_heads(&probs, &values, None, Some(&tokens), None);
        // head 0 rows are one-hot: 3/4 of entries exactly zero
        assert!((s[0].exact_zero_frac - 0.75).abs() < 1e-9);
        assert_eq!(s[1].exact_zero_frac, 0.0);
    }

    #[test]
    fn gates_mean() {
        let (probs, values, tokens) = noop_setup();
        let gates = Tensor::from_fn(&[1, 2, 4], |i| if i < 4 { 0.0 } else { 1.0 });
        let s = summarize_heads(&probs, &values, Some(&gates), Some(&tokens), None);
        assert_eq!(s[0].mean_gate, Some(0.0));
        assert_eq!(s[1].mean_gate, Some(1.0));
    }

    #[test]
    fn heatmap_renders() {
        let (probs, ..) = noop_setup();
        let hm = ascii_heatmap(&probs, 0, 0, 8);
        assert_eq!(hm.lines().count(), 4);
        assert!(hm.starts_with('@'), "{hm}");
    }
}
