//! Parameter accounting (Table 4): gating-module memory overhead per
//! attention layer, computed from the manifest's parameter inventory.

use crate::runtime::artifact::Manifest;

#[derive(Debug, Clone)]
pub struct GateOverhead {
    pub attention: String,
    pub extra_params_per_layer: usize,
    pub total_params: usize,
    pub gate_params: usize,
    /// Equivalent "extra tokens" (gate params / d_model), Table 4's unit.
    pub extra_tokens: f64,
    pub overhead_frac: f64,
}

pub fn gate_overhead(m: &Manifest) -> GateOverhead {
    let total: usize = m.params.iter().map(|p| p.shape.iter().product::<usize>()).sum();
    let gate: usize = m
        .params
        .iter()
        .filter(|p| p.name.contains(".gate."))
        .map(|p| p.shape.iter().product::<usize>())
        .sum();
    let per_layer = if m.config.n_layers > 0 { gate / m.config.n_layers } else { 0 };
    GateOverhead {
        attention: m.config.attention.clone(),
        extra_params_per_layer: per_layer,
        total_params: total,
        gate_params: gate,
        extra_tokens: per_layer as f64 / m.config.d_model as f64,
        overhead_frac: gate as f64 / total as f64,
    }
}

/// Closed-form expected gate parameter count per layer (Table 4's formulas)
/// — cross-checked against the manifest in tests/benches.
pub fn expected_gate_params(attention: &str, n_heads: usize, d_head: usize,
                            d_model: usize, n_hid: usize) -> usize {
    match attention {
        "softmax" => 0,
        "gated_linear" => n_heads * (d_head + 1),
        "gated_mlp" => n_heads * (n_hid * (d_head + 2) + 1),
        "gated_allheads" => n_heads * (d_model + 1),
        other => panic!("unknown attention {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formulas_match_paper_table4() {
        // BERT-base numbers: H=12, d_head=64, d_model=768.
        assert_eq!(expected_gate_params("gated_linear", 12, 64, 768, 4), 12 * 65);
        assert_eq!(
            expected_gate_params("gated_mlp", 12, 64, 768, 4),
            12 * (4 * 66 + 1)
        );
        assert_eq!(
            expected_gate_params("gated_allheads", 12, 64, 768, 4),
            12 * 769
        );
        assert_eq!(expected_gate_params("softmax", 12, 64, 768, 4), 0);
    }
}
