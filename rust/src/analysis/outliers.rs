//! Outlier localization (paper §3, Fig 1): values exceeding 6 standard
//! deviations from the tensor mean (footnote 1, following Bondarenko et
//! al. 2021), counted per hidden dimension and per token position.

use std::collections::BTreeMap;

use crate::util::tensor::Tensor;

pub const OUTLIER_SIGMA: f32 = 6.0;

/// Outlier counts for a stream of (B, T, D) activation tensors.
#[derive(Debug, Default, Clone)]
pub struct OutlierCounts {
    /// hidden dimension -> count
    pub per_dim: BTreeMap<usize, u64>,
    /// token position -> count
    pub per_pos: BTreeMap<usize, u64>,
    /// token id at the outlier position (if token batch supplied) -> count
    pub per_token: BTreeMap<i32, u64>,
    pub total: u64,
    pub values_seen: u64,
}

impl OutlierCounts {
    /// Scan one (B, T, D) tensor; `tokens` (B*T) optionally attributes
    /// outliers to vocabulary items (Fig 1's "97% of outliers sit at
    /// delimiter positions" observation).
    pub fn observe(&mut self, act: &Tensor, tokens: Option<&[i32]>) {
        let dims = act.shape();
        assert_eq!(dims.len(), 3, "expected (B,T,D), got {dims:?}");
        let (b, t, d) = (dims[0], dims[1], dims[2]);
        let data = act.data();
        let mean = crate::util::stats::mean(data) as f32;
        let std = crate::util::stats::std_dev(data) as f32;
        let thr = OUTLIER_SIGMA * std;
        self.values_seen += data.len() as u64;
        if std == 0.0 {
            return;
        }
        for bi in 0..b {
            for ti in 0..t {
                let base = (bi * t + ti) * d;
                for di in 0..d {
                    if (data[base + di] - mean).abs() > thr {
                        *self.per_dim.entry(di).or_default() += 1;
                        *self.per_pos.entry(ti).or_default() += 1;
                        if let Some(toks) = tokens {
                            *self.per_token.entry(toks[bi * t + ti]).or_default() += 1;
                        }
                        self.total += 1;
                    }
                }
            }
        }
    }

    /// Dimensions sorted by outlier count, descending.
    pub fn top_dims(&self, k: usize) -> Vec<(usize, u64)> {
        let mut v: Vec<_> = self.per_dim.iter().map(|(&d, &c)| (d, c)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1));
        v.truncate(k);
        v
    }

    /// Fraction of outliers occurring at positions holding a token in
    /// `token_set` (e.g. the delimiter set).
    pub fn token_fraction(&self, token_set: &[i32]) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let hits: u64 = self
            .per_token
            .iter()
            .filter(|(t, _)| token_set.contains(t))
            .map(|(_, c)| c)
            .sum();
        hits as f64 / self.total as f64
    }

    /// Map a hidden dimension to its attention head (paper §3: BERT head i
    /// owns the consecutive d_head-feature slice).
    pub fn dim_to_head(dim: usize, d_head: usize) -> usize {
        dim / d_head
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn act_with_outlier(b: usize, t: usize, d: usize, pos: usize, dim: usize) -> Tensor {
        let mut a = Tensor::from_fn(&[b, t, d], |i| ((i * 37 % 17) as f32 - 8.0) * 0.01);
        a.set(&[0, pos, dim], 500.0);
        a
    }

    #[test]
    fn finds_planted_outlier() {
        let mut c = OutlierCounts::default();
        let a = act_with_outlier(2, 8, 16, 3, 5);
        c.observe(&a, None);
        assert_eq!(c.total, 1);
        assert_eq!(c.per_dim.get(&5), Some(&1));
        assert_eq!(c.per_pos.get(&3), Some(&1));
    }

    #[test]
    fn attributes_tokens() {
        let mut c = OutlierCounts::default();
        let a = act_with_outlier(1, 8, 16, 2, 0);
        let tokens: Vec<i32> = (0..8).collect();
        c.observe(&a, Some(&tokens));
        assert_eq!(c.per_token.get(&2), Some(&1));
        assert_eq!(c.token_fraction(&[2]), 1.0);
        assert_eq!(c.token_fraction(&[7]), 0.0);
    }

    #[test]
    fn no_outliers_in_uniform_noise() {
        let mut rng = crate::util::rng::Rng::new(1);
        let a = Tensor::from_fn(&[4, 16, 32], |_| rng.f32() - 0.5);
        let mut c = OutlierCounts::default();
        c.observe(&a, None);
        // uniform noise never exceeds ~1.8σ
        assert_eq!(c.total, 0);
    }

    #[test]
    fn head_attribution() {
        assert_eq!(OutlierCounts::dim_to_head(0, 16), 0);
        assert_eq!(OutlierCounts::dim_to_head(17, 16), 1);
        assert_eq!(OutlierCounts::dim_to_head(63, 16), 3);
    }

    #[test]
    fn top_dims_sorted() {
        let mut c = OutlierCounts::default();
        let mut a = act_with_outlier(1, 8, 16, 1, 3);
        a.set(&[0, 2, 3], 500.0);
        a.set(&[0, 4, 7], -500.0);
        c.observe(&a, None);
        let top = c.top_dims(2);
        assert_eq!(top[0], (3, 2));
        assert_eq!(top[1], (7, 1));
    }
}
