//! Outlier and attention-pattern analysis (paper §3 and Figs 1-3, 8-17).

pub mod attention;
pub mod outliers;
pub mod params;
