//! Paper-style table rendering: fixed-width aligned columns with
//! `mean±std` cells, printed to stdout and appended to EXPERIMENTS.md by
//! the bench harness.

/// Render an aligned text table.
pub fn render(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.chars().count()).collect();
    for row in rows {
        assert_eq!(row.len(), ncol, "row width mismatch");
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.chars().count());
        }
    }
    let mut out = String::new();
    let sep_row = |out: &mut String| {
        for (i, w) in widths.iter().enumerate() {
            out.push_str(if i == 0 { "|-" } else { "-|-" });
            out.push_str(&"-".repeat(*w));
        }
        out.push_str("-|\n");
    };
    let fmt_row = |out: &mut String, cells: &[String]| {
        for (i, (c, w)) in cells.iter().zip(&widths).enumerate() {
            out.push_str(if i == 0 { "| " } else { " | " });
            out.push_str(c);
            out.push_str(&" ".repeat(w - c.chars().count()));
        }
        out.push_str(" |\n");
    };
    fmt_row(&mut out, &headers.iter().map(|h| h.to_string()).collect::<Vec<_>>());
    sep_row(&mut out);
    for row in rows {
        fmt_row(&mut out, row);
    }
    out
}

/// Format a float with magnitude-adaptive precision (ppl 4.52 vs kurtosis
/// 3076 render sensibly in the same table).
pub fn fnum(x: f64) -> String {
    if !x.is_finite() {
        return format!("{x}");
    }
    let a = x.abs();
    if a >= 1000.0 {
        format!("{x:.0}")
    } else if a >= 100.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.2}")
    }
}

/// `mean±std` cell.
pub fn cell(ms: &crate::util::stats::MeanStd) -> String {
    format!("{}±{}", fnum(ms.mean), fnum(ms.std))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::MeanStd;

    #[test]
    fn renders_aligned() {
        let t = render(
            &["Method", "ppl"],
            &[
                vec!["Vanilla".into(), "4.49".into()],
                vec!["Clipped softmax".into(), "4.39".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "{t}");
        assert!(lines[3].contains("Clipped softmax"));
    }

    #[test]
    fn adaptive_precision() {
        assert_eq!(fnum(4.516), "4.52");
        assert_eq!(fnum(735.2), "735.2");
        assert_eq!(fnum(3076.4), "3076");
    }

    #[test]
    fn meanstd_cell() {
        let c = cell(&MeanStd::from(&[4.0, 5.0]));
        assert!(c.starts_with("4.50±"), "{c}");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        render(&["a", "b"], &[vec!["x".into()]]);
    }
}
