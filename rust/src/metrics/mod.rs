//! Evaluation metrics (§5): perplexity / accuracy, and the two outlier
//! metrics the paper reports in every table — average max infinity norm and
//! average kurtosis of attention-layer outputs.

pub mod table;

use std::collections::BTreeMap;

/// Perplexity from accumulated NLL sums.
pub fn perplexity(sum_nll: f64, count: f64) -> f64 {
    (sum_nll / count.max(1.0)).exp()
}

/// Streaming raw-moment accumulator for kurtosis over many batches without
/// storing values (§5: "kurtosis of x averaged across all layers").
#[derive(Debug, Clone, Default)]
pub struct MomentAccum {
    pub n: f64,
    s1: f64,
    s2: f64,
    s3: f64,
    s4: f64,
}

impl MomentAccum {
    pub fn observe(&mut self, xs: &[f32]) {
        for &x in xs {
            let x = x as f64;
            self.n += 1.0;
            self.s1 += x;
            self.s2 += x * x;
            self.s3 += x * x * x;
            self.s4 += x * x * x * x;
        }
    }

    /// Pearson kurtosis m4/m2² from raw moments.
    pub fn kurtosis(&self) -> f64 {
        if self.n < 2.0 {
            return 0.0;
        }
        let m = self.s1 / self.n;
        let m2 = self.s2 / self.n - m * m;
        let m3 = self.s3 / self.n - 3.0 * m * self.s2 / self.n + 2.0 * m * m * m;
        let m4 = self.s4 / self.n - 4.0 * m * self.s3 / self.n
            + 6.0 * m * m * self.s2 / self.n
            - 3.0 * m * m * m * m;
        let _ = m3;
        if m2 <= 0.0 {
            0.0
        } else {
            m4 / (m2 * m2)
        }
    }
}

/// The paper's per-model outlier metrics, accumulated over an eval stream:
/// * `max_inf_norm` — ‖x‖∞ of attention-layer outputs, max over layers,
///   averaged across eval batches;
/// * `avg_kurtosis` — kurtosis per layer over the whole stream, averaged
///   across layers.
#[derive(Debug, Default)]
pub struct OutlierMetrics {
    per_layer: BTreeMap<String, MomentAccum>,
    batch_inf_norms: Vec<f32>,
}

impl OutlierMetrics {
    /// Feed one batch's block outputs: (layer name, data).
    pub fn observe_batch(&mut self, layers: &[(String, &[f32])]) {
        let mut batch_max = 0.0f32;
        for (name, data) in layers {
            self.per_layer.entry(name.clone()).or_default().observe(data);
            batch_max = batch_max.max(crate::util::stats::inf_norm(data));
        }
        self.batch_inf_norms.push(batch_max);
    }

    pub fn max_inf_norm(&self) -> f64 {
        if self.batch_inf_norms.is_empty() {
            return 0.0;
        }
        self.batch_inf_norms.iter().map(|&x| x as f64).sum::<f64>()
            / self.batch_inf_norms.len() as f64
    }

    pub fn avg_kurtosis(&self) -> f64 {
        if self.per_layer.is_empty() {
            return 0.0;
        }
        self.per_layer.values().map(MomentAccum::kurtosis).sum::<f64>()
            / self.per_layer.len() as f64
    }

    pub fn layer_kurtosis(&self) -> Vec<(String, f64)> {
        self.per_layer
            .iter()
            .map(|(k, v)| (k.clone(), v.kurtosis()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perplexity_of_uniform() {
        // NLL = ln(V) per token → ppl = V.
        let v = 256.0f64;
        let ppl = perplexity(v.ln() * 100.0, 100.0);
        assert!((ppl - v).abs() < 1e-6);
    }

    #[test]
    fn moment_kurtosis_matches_direct() {
        let mut rng = crate::util::rng::Rng::new(4);
        let xs: Vec<f32> = (0..5000).map(|_| rng.normal() * 2.0 + 1.0).collect();
        let mut acc = MomentAccum::default();
        acc.observe(&xs);
        let direct = crate::util::stats::kurtosis(&xs);
        assert!(
            (acc.kurtosis() - direct).abs() < 1e-6 * direct.abs().max(1.0),
            "{} vs {direct}",
            acc.kurtosis()
        );
    }

    #[test]
    fn outlier_metrics_aggregate() {
        let mut m = OutlierMetrics::default();
        let a = vec![1.0f32; 100];
        let mut b = vec![0.1f32; 100];
        b[0] = -50.0;
        m.observe_batch(&[("L0".into(), a.as_slice()), ("L1".into(), b.as_slice())]);
        m.observe_batch(&[("L0".into(), a.as_slice()), ("L1".into(), a.as_slice())]);
        // batch 1 inf norm = 50, batch 2 = 1 → mean 25.5
        assert!((m.max_inf_norm() - 25.5).abs() < 1e-9);
        // L1 kurtosis is huge, L0 is 0 (constant)
        assert!(m.avg_kurtosis() > 10.0);
        assert_eq!(m.layer_kurtosis().len(), 2);
    }
}
