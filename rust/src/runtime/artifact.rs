//! Artifact manifests: the contract between `python/compile/aot.py` and the
//! rust coordinator. A manifest fully describes one model config's
//! programs (flat input/output lists with names, shapes and dtypes), its
//! parameter inventory (decay/quantize flags), and the ordered activation
//! quant-point list shared with the calibrator.
//!
//! The manifest carries a format `version` (`aot.py::MANIFEST_VERSION`);
//! feature gates compare against it so "re-run `make artifacts`" errors
//! can say *which* version introduced the missing piece (v5 added the
//! per-row `serve_score` program the PJRT serving engine needs).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::runtime::client::Runtime;
use crate::runtime::package::PackageInfo;
use crate::runtime::program::Program;
use crate::util::json::Json;

/// One program input/output tensor: name, shape, dtype ("float32"/"int32").
#[derive(Debug, Clone, PartialEq)]
pub struct IoDesc {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoDesc {
    fn from_json(j: &Json) -> Result<IoDesc> {
        Ok(IoDesc {
            name: j.req("name")?.as_str().context("io name")?.to_string(),
            shape: j.req("shape")?.as_usize_vec().context("io shape")?,
            dtype: j.req("dtype")?.as_str().context("io dtype")?.to_string(),
        })
    }

    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ProgramDesc {
    pub file: String,
    pub inputs: Vec<IoDesc>,
    pub outputs: Vec<IoDesc>,
}

/// Parameter metadata (subset of `python/compile/model.py::ParamSpec`).
#[derive(Debug, Clone)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub decay: bool,
    /// Weight-quantized by the PTQ pipeline (final head excluded, §5).
    pub quantize: bool,
    pub ln_gamma: bool,
}

/// Model-config fields the coordinator needs (mirrors configs.py).
#[derive(Debug, Clone)]
pub struct ConfigInfo {
    pub name: String,
    pub family: String,
    pub attention: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub vocab_size: usize,
    pub n_classes: usize,
    pub patch_dim: usize,
    pub batch_size: usize,
    pub causal: bool,
    pub use_gate: bool,
    pub objective: String,
}

/// Manifest version that introduced the `serve_score` program (the
/// per-row scoring entry point `qtx serve --engine pjrt` executes).
pub const SERVE_MANIFEST_VERSION: u32 = 5;

#[derive(Debug)]
pub struct Manifest {
    /// Format version written by `aot.py` (0 for pre-versioned manifests,
    /// which predate the field itself).
    pub version: u32,
    pub config: ConfigInfo,
    pub params: Vec<ParamInfo>,
    pub programs: HashMap<String, ProgramDesc>,
    pub quant_points: Vec<String>,
    /// Manifest-v2 package block (checksummed entries + provenance).
    /// `None` is the explicit compat shim for legacy dirs: they load
    /// read-only, but `qtx install` / `/admin/reload` refuse them and
    /// `qtx doctor` reports *fixable*. A present-but-malformed block is
    /// a parse error — fail-closed, never a partial load.
    pub package: Option<PackageInfo>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let c = j.req("config")?;
        let geti = |k: &str| -> Result<usize> {
            c.req(k)?.as_usize().with_context(|| format!("config.{k}"))
        };
        let gets = |k: &str| -> Result<String> {
            Ok(c.req(k)?.as_str().with_context(|| format!("config.{k}"))?.to_string())
        };
        let config = ConfigInfo {
            name: gets("name")?,
            family: gets("family")?,
            attention: gets("attention")?,
            n_layers: geti("n_layers")?,
            d_model: geti("d_model")?,
            n_heads: geti("n_heads")?,
            seq_len: geti("seq_len")?,
            vocab_size: geti("vocab_size")?,
            n_classes: geti("n_classes")?,
            patch_dim: geti("patch_dim")?,
            batch_size: geti("batch_size")?,
            causal: c.req("causal")?.as_bool().context("config.causal")?,
            use_gate: c.req("use_gate")?.as_bool().context("config.use_gate")?,
            objective: gets("objective")?,
        };

        let mut params = Vec::new();
        for p in j.req("params")?.as_arr().context("params")? {
            params.push(ParamInfo {
                name: p.req("name")?.as_str().context("param name")?.to_string(),
                shape: p.req("shape")?.as_usize_vec().context("param shape")?,
                decay: p.req("decay")?.as_bool().unwrap_or(false),
                quantize: p.req("quantize")?.as_bool().unwrap_or(false),
                ln_gamma: p.req("ln_gamma")?.as_bool().unwrap_or(false),
            });
        }

        let mut programs = HashMap::new();
        for (name, pj) in j.req("programs")?.as_obj().context("programs")? {
            let inputs = pj
                .req("inputs")?
                .as_arr()
                .context("inputs")?
                .iter()
                .map(IoDesc::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = pj
                .req("outputs")?
                .as_arr()
                .context("outputs")?
                .iter()
                .map(IoDesc::from_json)
                .collect::<Result<Vec<_>>>()?;
            programs.insert(
                name.clone(),
                ProgramDesc {
                    file: pj.req("file")?.as_str().context("file")?.to_string(),
                    inputs,
                    outputs,
                },
            );
        }

        let quant_points = j
            .req("quant_points")?
            .as_arr()
            .context("quant_points")?
            .iter()
            .map(|v| Ok(v.as_str().context("quant point")?.to_string()))
            .collect::<Result<Vec<_>>>()?;

        // Older manifests predate the version field: report them as v0
        // rather than failing the parse.
        let version = j.get("version").and_then(Json::as_usize).unwrap_or(0) as u32;

        // Absent package block = legacy compat shim. Present = must parse
        // cleanly (unknown schema / duplicate paths / missing fields are
        // descriptive errors, see runtime::package).
        let package = match j.get("package") {
            None | Some(Json::Null) => None,
            Some(p) => Some(PackageInfo::from_json(p).context("manifest package block")?),
        };

        Ok(Manifest { version, config, params, programs, quant_points, package })
    }

    /// Human-readable version for error messages and `/healthz` payloads.
    pub fn version_label(&self) -> String {
        if self.version == 0 {
            "unversioned (pre-v5)".to_string()
        } else {
            format!("v{}", self.version)
        }
    }

    /// Human-readable package-schema tier for error messages and the
    /// `/healthz` startup-failure payload.
    pub fn schema_label(&self) -> String {
        match &self.package {
            Some(p) => format!("package schema {}", p.schema),
            None => "legacy manifest (no package block)".to_string(),
        }
    }

    /// Gate for the PJRT serving path: errors when the artifact lacks the
    /// `serve_score` program, naming the found vs. required manifest
    /// version so "re-run `make artifacts`" is actionable.
    pub fn require_serve_score(&self) -> Result<()> {
        if self.programs.contains_key("serve_score") {
            return Ok(());
        }
        bail!(
            "artifact for {} has no `serve_score` program (manifest {}, `qtx serve --engine \
             pjrt` needs v{SERVE_MANIFEST_VERSION}+) — re-run `make artifacts` to rebuild it",
            self.config.name,
            self.version_label()
        )
    }

    /// [`Manifest::require_serve_score`] with the artifact directory and
    /// package-schema tier in the message, so the `/healthz`
    /// startup-failure payload names exactly which on-disk dir (and which
    /// manifest generation) the operator has to fix.
    pub fn require_serve_score_at(&self, dir: &Path) -> Result<()> {
        self.require_serve_score().with_context(|| {
            format!("artifact dir {} ({})", dir.display(), self.schema_label())
        })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Manifest::parse(&text).with_context(|| format!("parsing {path:?}"))
    }
}

/// A loaded artifact directory: manifest + lazily compiled programs.
///
/// Compilation is cached per program name; a full experiment touches
/// `init`, `train_step`, `eval_step`, `act_collect` and `eval_quant` once
/// each, and the compiled executables are reused across seeds and
/// hyperparameter sweep rows (gamma/zeta/lr/... are runtime inputs).
pub struct Artifact {
    pub dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Program>>>,
}

impl Artifact {
    pub fn load(artifacts_root: &Path, config_name: &str) -> Result<Artifact> {
        let dir = artifacts_root.join(config_name);
        let manifest = Manifest::load(&dir)?;
        if manifest.config.name != config_name {
            bail!(
                "manifest config name {:?} does not match directory {:?}",
                manifest.config.name,
                config_name
            );
        }
        Ok(Artifact { dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Compile (or fetch the cached) program by name.
    pub fn program(&self, rt: &Runtime, name: &str) -> Result<Rc<Program>> {
        if let Some(p) = self.cache.borrow().get(name) {
            return Ok(p.clone());
        }
        let desc = self
            .manifest
            .programs
            .get(name)
            .with_context(|| {
                format!("program {name:?} not in manifest for {}", self.manifest.config.name)
            })?;
        let t0 = std::time::Instant::now();
        let exe = rt.compile_hlo_text(&self.dir.join(&desc.file))?;
        let prog = Rc::new(Program::new(
            format!("{}::{}", self.manifest.config.name, name),
            exe,
            desc.inputs.clone(),
            desc.outputs.clone(),
        ));
        crate::util::log::debug(&format!(
            "compiled {} in {:.2}s",
            prog.name,
            t0.elapsed().as_secs_f64()
        ));
        self.cache.borrow_mut().insert(name.to_string(), prog.clone());
        Ok(prog)
    }

    /// Indices (into the flat param list) and infos of weight-quantizable
    /// parameters.
    pub fn quantizable_params(&self) -> Vec<(usize, &ParamInfo)> {
        self.manifest
            .params
            .iter()
            .enumerate()
            .filter(|(_, p)| p.quantize)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "fingerprint": "x",
      "config": {"name":"c","family":"bert","attention":"softmax",
        "n_layers":2,"d_model":8,"n_heads":2,"seq_len":4,"vocab_size":16,
        "n_classes":0,"patch_dim":0,"batch_size":2,"causal":false,
        "use_gate":false,"objective":"mlm","d_head":4,"ln_placement":"post",
        "patch_ln":false,"gate_hidden":4,"init_std":0.02,"adam_b1":0.9,
        "adam_b2":0.999,"weight_decay":0.01,"grad_clip":1.0,"d_ff":32},
      "params": [
        {"name":"tok_emb","shape":[16,8],"init":"normal","decay":true,"quantize":true,"ln_gamma":false},
        {"name":"head.b","shape":[16],"init":"zeros","decay":false,"quantize":false,"ln_gamma":false}
      ],
      "programs": {
        "init": {"file":"init.hlo.txt",
          "inputs":[{"name":"seed","shape":[],"dtype":"int32"}],
          "outputs":[{"name":"param::tok_emb","shape":[16,8],"dtype":"float32"}]}
      },
      "quant_points": ["embed","L0.q"]
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.config.name, "c");
        assert_eq!(m.config.d_model, 8);
        assert_eq!(m.params.len(), 2);
        assert!(m.params[0].quantize);
        assert!(!m.params[1].quantize);
        assert_eq!(m.programs["init"].inputs[0].dtype, "int32");
        assert_eq!(m.quant_points, ["embed", "L0.q"]);
    }

    #[test]
    fn rejects_missing_keys() {
        assert!(Manifest::parse("{}").is_err());
    }

    /// Pre-versioned manifests parse as v0 and the serve gate names both
    /// the found and the required version.
    #[test]
    fn version_gate_names_found_and_required() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.version, 0);
        assert_eq!(m.version_label(), "unversioned (pre-v5)");
        let err = m.require_serve_score().unwrap_err().to_string();
        assert!(err.contains("unversioned (pre-v5)"), "{err}");
        assert!(err.contains(&format!("v{SERVE_MANIFEST_VERSION}+")), "{err}");
        assert!(err.contains("make artifacts"), "{err}");

        let versioned = MINI.replacen("{", "{\n  \"version\": 5,", 1);
        let m5 = Manifest::parse(&versioned).unwrap();
        assert_eq!(m5.version, 5);
        assert_eq!(m5.version_label(), "v5");
        // Still errors (this manifest has no serve_score program), but now
        // reports the parsed version.
        let err5 = m5.require_serve_score().unwrap_err().to_string();
        assert!(err5.contains("manifest v5"), "{err5}");
    }

    /// Manifests without a package block load through the compat shim;
    /// a present block must parse fail-closed.
    #[test]
    fn package_block_is_optional_but_fail_closed() {
        let m = Manifest::parse(MINI).unwrap();
        assert!(m.package.is_none());
        assert_eq!(m.schema_label(), "legacy manifest (no package block)");

        let sha = crate::runtime::package::sha256_hex(b"payload");
        let block = format!(
            r#"{{
  "package": {{"schema": {schema}, "install_id": "abc123",
    "entries": [{{"path":"init.hlo.txt","kind":"program","bytes":7,"sha256":"{sha}"}}],
    "provenance": {{"fingerprint":"x","config":"c","variant":"softmax",
      "calibration_id":"q","toolchain":"t"}}}},"#,
            schema = crate::runtime::package::PACKAGE_SCHEMA
        );
        let packaged = MINI.replacen("{", &block, 1);
        let mp = Manifest::parse(&packaged).unwrap();
        let pkg = mp.package.expect("package block parsed");
        assert_eq!(pkg.install_id, "abc123");
        assert_eq!(pkg.entries.len(), 1);
        assert_eq!(mp.schema_label(), "package schema 2");

        // Unknown schema: the whole manifest parse fails with a
        // descriptive error — never a partial load.
        let future = packaged.replacen("\"schema\": 2", "\"schema\": 9", 1);
        assert_ne!(future, packaged);
        let err = format!("{:#}", Manifest::parse(&future).unwrap_err());
        assert!(err.contains("unsupported package schema 9"), "{err}");

        // Duplicate entry paths: same fail-closed contract.
        let dup = packaged.replacen(
            "\"entries\": [{",
            &format!(
                "\"entries\": [{{\"path\":\"init.hlo.txt\",\"kind\":\"program\",\
                 \"bytes\":7,\"sha256\":\"{sha}\"}},{{"
            ),
            1,
        );
        assert_ne!(dup, packaged);
        let err = format!("{:#}", Manifest::parse(&dup).unwrap_err());
        assert!(err.contains("duplicate package entry path"), "{err}");
    }

    /// The serve gate's `_at` variant names the artifact dir and the
    /// package-schema tier — the `/healthz` startup-failure contract.
    #[test]
    fn serve_gate_at_names_dir_and_schema() {
        let m = Manifest::parse(MINI).unwrap();
        let err = format!(
            "{:#}",
            m.require_serve_score_at(Path::new("/data/artifacts/c")).unwrap_err()
        );
        assert!(err.contains("/data/artifacts/c"), "{err}");
        assert!(err.contains("legacy manifest (no package block)"), "{err}");
        assert!(err.contains("serve_score"), "{err}");
        assert!(err.contains("make artifacts"), "{err}");
    }
}
