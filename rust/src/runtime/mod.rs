//! PJRT runtime: loads the AOT artifacts (`artifacts/<config>/*.hlo.txt`)
//! produced by `make artifacts` and executes them on the CPU PJRT client.
//!
//! Interchange is HLO *text* (xla_extension 0.5.1 rejects jax>=0.5 protos
//! with 64-bit instruction ids; the text parser reassigns ids). All program
//! IO is addressed by *name* through the manifest — rust never guesses
//! positions.

pub mod artifact;
pub mod client;
pub mod package;
pub mod program;

pub use artifact::{Artifact, IoDesc, Manifest, ParamInfo, ProgramDesc, SERVE_MANIFEST_VERSION};
pub use client::Runtime;
pub use package::{
    DoctorReport, DoctorVerdict, PackageEntry, PackageInfo, Provenance, StagedInstall,
    PACKAGE_SCHEMA,
};
pub use program::{Program, Value};
