//! The PJRT CPU client wrapper. One `Runtime` per process; executables are
//! compiled through it and share its device.

use anyhow::Result;

/// Process-wide PJRT client handle.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU PJRT client. Prints nothing; PJRT logs its own
    /// creation line on stderr.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile HLO text into a loaded executable.
    pub fn compile_hlo_text(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }
}
