//! Named-IO program wrapper around a PJRT loaded executable.
//!
//! The AOT programs return one tuple (jax lowers with `return_tuple=True`);
//! `run_raw` decomposes it back into per-output literals. The training loop
//! keeps its state as `xla::Literal`s and threads them straight back into
//! the next step, so the only per-step host conversions are the batch
//! (rust-generated anyway) and the scalar loss.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::runtime::artifact::IoDesc;
use crate::util::tensor::{IntTensor, Tensor};

/// A host value crossing the runtime boundary.
#[derive(Debug, Clone)]
pub enum Value {
    F32(Tensor),
    I32(IntTensor),
}

impl Value {
    pub fn scalar(v: f32) -> Value {
        Value::F32(Tensor::scalar(v))
    }

    pub fn scalar_i32(v: i32) -> Value {
        Value::I32(IntTensor::scalar(v))
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => t.shape(),
            Value::I32(t) => t.shape(),
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            Value::F32(_) => "float32",
            Value::I32(_) => "int32",
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Value::F32(t) => xla::Literal::vec1(t.data()).reshape(&dims)?,
            Value::I32(t) => xla::Literal::vec1(t.data()).reshape(&dims)?,
        };
        Ok(lit)
    }
}

/// Convert a (non-tuple) literal to a host value.
pub fn literal_to_value(lit: &xla::Literal) -> Result<Value> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            Ok(Value::F32(Tensor::new(dims, lit.to_vec::<f32>()?)?))
        }
        xla::ElementType::S32 => {
            Ok(Value::I32(IntTensor::new(dims, lit.to_vec::<i32>()?)?))
        }
        other => bail!("unsupported literal element type {other:?}"),
    }
}

/// Fetch a scalar f32 out of a literal.
pub fn literal_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

pub struct Program {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    pub inputs: Vec<IoDesc>,
    pub outputs: Vec<IoDesc>,
    input_idx: HashMap<String, usize>,
    output_idx: HashMap<String, usize>,
}

impl Program {
    pub fn new(
        name: String,
        exe: xla::PjRtLoadedExecutable,
        inputs: Vec<IoDesc>,
        outputs: Vec<IoDesc>,
    ) -> Program {
        let input_idx = inputs.iter().enumerate().map(|(i, d)| (d.name.clone(), i)).collect();
        let output_idx = outputs.iter().enumerate().map(|(i, d)| (d.name.clone(), i)).collect();
        Program { name, exe, inputs, outputs, input_idx, output_idx }
    }

    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.input_idx
            .get(name)
            .copied()
            .with_context(|| format!("{}: no input named {name:?}", self.name))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.output_idx
            .get(name)
            .copied()
            .with_context(|| format!("{}: no output named {name:?}", self.name))
    }

    /// Indices of all outputs whose name starts with `prefix`, in manifest
    /// order (e.g. every `param::*` of train_step).
    pub fn output_indices_with_prefix(&self, prefix: &str) -> Vec<usize> {
        self.outputs
            .iter()
            .enumerate()
            .filter(|(_, d)| d.name.starts_with(prefix))
            .map(|(i, _)| i)
            .collect()
    }

    /// Execute with pre-built literals (the hot path). Accepts owned or
    /// borrowed literals so the training loop can mix persistent state refs
    /// with per-step batch literals. Returns the decomposed per-output
    /// literal list.
    pub fn run_raw<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<xla::Literal>> {
        if args.len() != self.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.inputs.len(),
                args.len()
            );
        }
        let results = self.exe.execute::<L>(args)?;
        let mut tuple = results
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .with_context(|| format!("{}: no output buffer", self.name))?
            .to_literal_sync()?;
        let outs = tuple.decompose_tuple()?;
        if outs.len() != self.outputs.len() {
            bail!(
                "{}: manifest promises {} outputs, executable returned {}",
                self.name,
                self.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }

    /// Execute with host values, validating shapes/dtypes against the
    /// manifest (the convenient path for one-shot programs).
    pub fn run(&self, args: &[Value]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.inputs.len(),
                args.len()
            );
        }
        for (v, d) in args.iter().zip(&self.inputs) {
            if v.shape() != d.shape.as_slice() || v.dtype() != d.dtype {
                bail!(
                    "{}: input {:?} wants {:?}/{}, got {:?}/{}",
                    self.name,
                    d.name,
                    d.shape,
                    d.dtype,
                    v.shape(),
                    v.dtype()
                );
            }
        }
        let lits = args.iter().map(Value::to_literal).collect::<Result<Vec<_>>>()?;
        self.run_raw(&lits)
    }

    /// Build an input literal list from named values; every input must be
    /// provided exactly once.
    pub fn build_inputs(&self, named: Vec<(&str, Value)>) -> Result<Vec<xla::Literal>> {
        let mut slots: Vec<Option<xla::Literal>> = (0..self.inputs.len()).map(|_| None).collect();
        for (name, v) in named {
            let i = self.input_index(name)?;
            let d = &self.inputs[i];
            if v.shape() != d.shape.as_slice() || v.dtype() != d.dtype {
                bail!(
                    "{}: input {name:?} wants {:?}/{}, got {:?}/{}",
                    self.name,
                    d.shape,
                    d.dtype,
                    v.shape(),
                    v.dtype()
                );
            }
            if slots[i].is_some() {
                bail!("{}: input {name:?} provided twice", self.name);
            }
            slots[i] = Some(v.to_literal()?);
        }
        slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| s.with_context(|| format!("{}: missing input {:?}", self.name, self.inputs[i].name)))
            .collect()
    }
}
