//! Operable artifact packages: the `"package"` block of manifest v2 and
//! the `qtx pack` / `qtx install` / `qtx doctor` machinery behind it.
//!
//! An artifact directory becomes *operable* when its `manifest.json`
//! carries a package block: a schema version, a deterministic install id,
//! a checksummed entry list covering every payload file, and a provenance
//! record (config fingerprint, softmax/gate variant, calibration id,
//! toolchain). With that block present the directory can be verified
//! byte-for-byte ([`verify_dir`]), copied atomically ([`stage`] +
//! [`commit`]) and hot-reloaded into a running `qtx serve`
//! (`POST /admin/reload`, see `docs/ARTIFACTS.md`).
//!
//! Parsing is fail-closed: an unknown package schema, a missing field, a
//! duplicate entry path or a checksum mismatch is a descriptive error,
//! never a partial load. Manifests that predate packaging (aot.py schema
//! 1/2 documents, no `"package"` key) still load read-only through the
//! explicit compat shim (`Manifest::package == None`); `qtx doctor`
//! reports them as *fixable*, not broken.
//!
//! Install is crash-safe by construction: entries are copied into a
//! sibling `.staging-<name>` directory under a `create_new` lockfile,
//! re-checksummed there, and the single commit point is one
//! `rename(2)` of the staging dir onto the destination. A crash at any
//! earlier point leaves the old destination untouched and a staging
//! leftover that `doctor` flags.
//!
//! SHA-256 is hand-rolled (FIPS 180-4) because the offline vendor set has
//! no hashing crate; the implementation is pinned by the standard test
//! vectors below.

use std::fs;
use std::io::Read;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Package schema this binary reads and writes. Pre-package manifests
/// (no `"package"` key) are the legacy tier; an explicit schema other
/// than this value is fail-closed rejected.
pub const PACKAGE_SCHEMA: u32 = 2;

/// Hex digits of the full sha256 kept in `install_id` / `calibration_id`.
const ID_HEX: usize = 16;
/// Hex digits reported as `artifact.sha256_short` in `/statz`.
const SHORT_HEX: usize = 12;

// ---- SHA-256 (FIPS 180-4) -------------------------------------------------

const SHA256_INIT: [u32; 8] = [
    0x6a09_e667, 0xbb67_ae85, 0x3c6e_f372, 0xa54f_f53a,
    0x510e_527f, 0x9b05_688c, 0x1f83_d9ab, 0x5be0_cd19,
];

#[rustfmt::skip]
const SHA256_K: [u32; 64] = [
    0x428a_2f98, 0x7137_4491, 0xb5c0_fbcf, 0xe9b5_dba5,
    0x3956_c25b, 0x59f1_11f1, 0x923f_82a4, 0xab1c_5ed5,
    0xd807_aa98, 0x1283_5b01, 0x2431_85be, 0x550c_7dc3,
    0x72be_5d74, 0x80de_b1fe, 0x9bdc_06a7, 0xc19b_f174,
    0xe49b_69c1, 0xefbe_4786, 0x0fc1_9dc6, 0x240c_a1cc,
    0x2de9_2c6f, 0x4a74_84aa, 0x5cb0_a9dc, 0x76f9_88da,
    0x983e_5152, 0xa831_c66d, 0xb003_27c8, 0xbf59_7fc7,
    0xc6e0_0bf3, 0xd5a7_9147, 0x06ca_6351, 0x1429_2967,
    0x27b7_0a85, 0x2e1b_2138, 0x4d2c_6dfc, 0x5338_0d13,
    0x650a_7354, 0x766a_0abb, 0x81c2_c92e, 0x9272_2c85,
    0xa2bf_e8a1, 0xa81a_664b, 0xc24b_8b70, 0xc76c_51a3,
    0xd192_e819, 0xd699_0624, 0xf40e_3585, 0x106a_a070,
    0x19a4_c116, 0x1e37_6c08, 0x2748_774c, 0x34b0_bcb5,
    0x391c_0cb3, 0x4ed8_aa4a, 0x5b9c_ca4f, 0x682e_6ff3,
    0x748f_82ee, 0x78a5_636f, 0x84c8_7814, 0x8cc7_0208,
    0x90be_fffa, 0xa450_6ceb, 0xbef9_a3f7, 0xc671_78f2,
];

/// Incremental SHA-256 hasher (streams files without loading them whole).
pub struct Sha256 {
    h: [u32; 8],
    buf: [u8; 64],
    buf_len: usize,
    total: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Sha256::new()
    }
}

impl Sha256 {
    pub fn new() -> Sha256 {
        Sha256 { h: SHA256_INIT, buf: [0; 64], buf_len: 0, total: 0 }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        self.total = self.total.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                compress(&mut self.h, &block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            let block: [u8; 64] = data[..64].try_into().expect("64-byte chunk");
            compress(&mut self.h, &block);
            data = &data[64..];
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    /// Consume the hasher and return the 64-char lowercase hex digest.
    pub fn finish_hex(mut self) -> String {
        let bits = self.total.wrapping_mul(8);
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0]);
        }
        // Length block: update() would re-count these 8 bytes, so compress
        // the final block directly.
        self.buf[56..64].copy_from_slice(&bits.to_be_bytes());
        let block = self.buf;
        compress(&mut self.h, &block);
        let mut out = String::with_capacity(64);
        for word in self.h {
            for b in word.to_be_bytes() {
                out.push_str(&format!("{b:02x}"));
            }
        }
        out
    }
}

fn compress(h: &mut [u32; 8], block: &[u8; 64]) {
    let mut w = [0u32; 64];
    for (i, chunk) in block.chunks_exact(4).enumerate() {
        w[i] = u32::from_be_bytes(chunk.try_into().expect("4-byte chunk"));
    }
    for i in 16..64 {
        let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
        let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
        w[i] = w[i - 16].wrapping_add(s0).wrapping_add(w[i - 7]).wrapping_add(s1);
    }
    let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh] = *h;
    for i in 0..64 {
        let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
        let ch = (e & f) ^ (!e & g);
        let t1 = hh
            .wrapping_add(s1)
            .wrapping_add(ch)
            .wrapping_add(SHA256_K[i])
            .wrapping_add(w[i]);
        let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
        let maj = (a & b) ^ (a & c) ^ (b & c);
        let t2 = s0.wrapping_add(maj);
        hh = g;
        g = f;
        f = e;
        e = d.wrapping_add(t1);
        d = c;
        c = b;
        b = a;
        a = t1.wrapping_add(t2);
    }
    for (slot, v) in h.iter_mut().zip([a, b, c, d, e, f, g, hh]) {
        *slot = slot.wrapping_add(v);
    }
}

/// One-shot digest of an in-memory byte string.
pub fn sha256_hex(data: &[u8]) -> String {
    let mut s = Sha256::new();
    s.update(data);
    s.finish_hex()
}

/// Streaming digest + size of a file on disk.
pub fn sha256_file(path: &Path) -> Result<(String, u64)> {
    let mut f = fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut hasher = Sha256::new();
    let mut buf = vec![0u8; 64 * 1024];
    let mut total = 0u64;
    loop {
        let n = f.read(&mut buf).with_context(|| format!("reading {path:?}"))?;
        if n == 0 {
            break;
        }
        hasher.update(&buf[..n]);
        total += n as u64;
    }
    Ok((hasher.finish_hex(), total))
}

// ---- package block types --------------------------------------------------

/// One payload file of a packaged artifact: relative path (always `/`
/// separated), coarse kind, exact size and content digest.
#[derive(Debug, Clone, PartialEq)]
pub struct PackageEntry {
    pub path: String,
    pub kind: String,
    pub bytes: u64,
    pub sha256: String,
}

/// Where the package came from: enough to answer "which config, which
/// outlier-removal variant, which calibration, built by what" without
/// reopening the payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Provenance {
    /// `aot.py` config fingerprint (empty for hand-built dirs).
    pub fingerprint: String,
    /// Config name (`bert_tiny_softmax`, ...).
    pub config: String,
    /// Softmax/gate variant, e.g. `softmax`, `clipped_softmax+gate`.
    pub variant: String,
    /// Digest of the ordered activation quant-point list.
    pub calibration_id: String,
    /// Producing tool, e.g. `qtx/0.1.0` or `aot.py`.
    pub toolchain: String,
}

/// The `"package"` block of a v2 manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct PackageInfo {
    pub schema: u32,
    /// Deterministic content id over the sorted entry list.
    pub install_id: String,
    pub entries: Vec<PackageEntry>,
    pub provenance: Provenance,
}

impl PackageInfo {
    /// Fail-closed parse of a `"package"` block: unknown schema, missing
    /// fields and duplicate entry paths are descriptive errors.
    pub fn from_json(j: &Json) -> Result<PackageInfo> {
        let schema = j
            .get("schema")
            .and_then(Json::as_usize)
            .context("package.schema must be an integer")? as u32;
        if schema != PACKAGE_SCHEMA {
            bail!(
                "unsupported package schema {schema} (this binary supports schema \
                 {PACKAGE_SCHEMA}) — refusing to load"
            );
        }
        let install_id = j
            .req("install_id")?
            .as_str()
            .context("package.install_id must be a string")?
            .to_string();
        let mut entries = Vec::new();
        for (i, e) in j.req("entries")?.as_arr().context("package.entries")?.iter().enumerate() {
            let field = |k: &str| -> Result<String> {
                Ok(e.req(k)?
                    .as_str()
                    .with_context(|| format!("package.entries[{i}].{k} must be a string"))?
                    .to_string())
            };
            let bytes = e
                .req("bytes")?
                .as_i64()
                .filter(|&n| n >= 0)
                .with_context(|| format!("package.entries[{i}].bytes must be an integer >= 0"))?
                as u64;
            let entry = PackageEntry {
                path: field("path")?,
                kind: field("kind")?,
                bytes,
                sha256: field("sha256")?,
            };
            if entry.path.is_empty() || entry.path.starts_with('/') || entry.path.contains("..") {
                bail!("package.entries[{i}]: invalid path {:?}", entry.path);
            }
            if entry.sha256.len() != 64 || !entry.sha256.bytes().all(|b| b.is_ascii_hexdigit()) {
                bail!(
                    "package.entries[{i}] ({}): sha256 must be 64 hex chars, got {:?}",
                    entry.path,
                    entry.sha256
                );
            }
            if let Some(dup) = entries.iter().find(|p: &&PackageEntry| p.path == entry.path) {
                bail!("duplicate package entry path {:?}", dup.path);
            }
            entries.push(entry);
        }
        let p = j.req("provenance")?;
        let ps = |k: &str| -> Result<String> {
            Ok(p.req(k)?
                .as_str()
                .with_context(|| format!("package.provenance.{k} must be a string"))?
                .to_string())
        };
        let provenance = Provenance {
            fingerprint: ps("fingerprint")?,
            config: ps("config")?,
            variant: ps("variant")?,
            calibration_id: ps("calibration_id")?,
            toolchain: ps("toolchain")?,
        };
        Ok(PackageInfo { schema, install_id, entries, provenance })
    }

    pub fn to_json(&self) -> Json {
        let entries = self
            .entries
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("path", Json::Str(e.path.clone())),
                    ("kind", Json::Str(e.kind.clone())),
                    ("bytes", Json::Num(e.bytes as f64)),
                    ("sha256", Json::Str(e.sha256.clone())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::Num(self.schema as f64)),
            ("install_id", Json::Str(self.install_id.clone())),
            ("entries", Json::Arr(entries)),
            (
                "provenance",
                Json::obj(vec![
                    ("fingerprint", Json::Str(self.provenance.fingerprint.clone())),
                    ("config", Json::Str(self.provenance.config.clone())),
                    ("variant", Json::Str(self.provenance.variant.clone())),
                    ("calibration_id", Json::Str(self.provenance.calibration_id.clone())),
                    ("toolchain", Json::Str(self.provenance.toolchain.clone())),
                ]),
            ),
        ])
    }

    /// Total payload bytes across entries.
    pub fn payload_bytes(&self) -> u64 {
        self.entries.iter().map(|e| e.bytes).sum()
    }

    /// First [`SHORT_HEX`] chars of the install id — the `/statz`
    /// `artifact.sha256_short` value.
    pub fn sha256_short(&self) -> String {
        self.install_id.chars().take(SHORT_HEX).collect()
    }
}

/// Deterministic install id: digest of the sorted `path bytes sha` lines.
fn install_id_for(entries: &[PackageEntry]) -> String {
    let mut h = Sha256::new();
    for e in entries {
        h.update(format!("{} {} {}\n", e.path, e.bytes, e.sha256).as_bytes());
    }
    h.finish_hex().chars().take(ID_HEX).collect()
}

/// Coarse payload classification, mirrored by `aot.py`.
fn kind_of(path: &str) -> &'static str {
    if path.ends_with(".hlo.txt") {
        "program"
    } else if path.ends_with(".ckpt") {
        "checkpoint"
    } else if path.ends_with(".json") {
        "meta"
    } else {
        "data"
    }
}

// ---- pack -----------------------------------------------------------------

/// Walk `dir` collecting payload files (everything except the manifest
/// itself and dotfiles — staging dirs and lockfiles start with `.`),
/// relative `/`-separated, sorted.
fn payload_files(dir: &Path) -> Result<Vec<String>> {
    fn walk(root: &Path, sub: &Path, out: &mut Vec<String>) -> Result<()> {
        for entry in fs::read_dir(sub).with_context(|| format!("listing {sub:?}"))? {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if name.starts_with('.') {
                continue;
            }
            let path = entry.path();
            if path.is_dir() {
                walk(root, &path, out)?;
            } else {
                let rel = path
                    .strip_prefix(root)
                    .expect("walked path under root")
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy().into_owned())
                    .collect::<Vec<_>>()
                    .join("/");
                if rel != "manifest.json" {
                    out.push(rel);
                }
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    walk(dir, dir, &mut out)?;
    out.sort();
    Ok(out)
}

/// Package an artifact directory in place: checksum every payload file,
/// derive provenance from the manifest, and write the `"package"` block
/// back into `manifest.json` (replacing any previous block). The manifest
/// itself is never an entry, so rewriting it cannot invalidate checksums.
pub fn pack(dir: &Path) -> Result<PackageInfo> {
    let manifest_path = dir.join("manifest.json");
    let text = fs::read_to_string(&manifest_path)
        .with_context(|| format!("reading {manifest_path:?} — not an artifact dir?"))?;
    let j = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {manifest_path:?}: {e}"))?;

    let mut entries = Vec::new();
    for rel in payload_files(dir)? {
        let (sha256, bytes) = sha256_file(&dir.join(&rel))?;
        entries.push(PackageEntry { kind: kind_of(&rel).to_string(), path: rel, bytes, sha256 });
    }
    if entries.is_empty() {
        bail!("{dir:?} has no payload files to pack");
    }

    let config = j
        .get("config")
        .and_then(|c| c.get("name"))
        .and_then(Json::as_str)
        .unwrap_or("unknown")
        .to_string();
    let attention = j
        .get("config")
        .and_then(|c| c.get("attention"))
        .and_then(Json::as_str)
        .unwrap_or("unknown");
    let gated = j
        .get("config")
        .and_then(|c| c.get("use_gate"))
        .and_then(Json::as_bool)
        .unwrap_or(false);
    let variant = if gated { format!("{attention}+gate") } else { attention.to_string() };
    let quant_points: Vec<&str> = j
        .get("quant_points")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_str).collect())
        .unwrap_or_default();
    let calibration_id: String =
        sha256_hex(quant_points.join(",").as_bytes()).chars().take(ID_HEX).collect();

    let info = PackageInfo {
        schema: PACKAGE_SCHEMA,
        install_id: install_id_for(&entries),
        entries,
        provenance: Provenance {
            fingerprint: j.get("fingerprint").and_then(Json::as_str).unwrap_or("").to_string(),
            config,
            variant,
            calibration_id,
            toolchain: concat!("qtx/", env!("CARGO_PKG_VERSION")).to_string(),
        },
    };

    let Json::Obj(mut kv) = j else { bail!("{manifest_path:?}: manifest is not a JSON object") };
    kv.retain(|(k, _)| k != "package");
    kv.push(("package".to_string(), info.to_json()));
    fs::write(&manifest_path, Json::Obj(kv).to_string())
        .with_context(|| format!("writing {manifest_path:?}"))?;
    Ok(info)
}

// ---- verify ---------------------------------------------------------------

/// Read the package block of `dir` without content verification. Errors
/// on legacy (pre-package) manifests — callers that tolerate those should
/// go through `Manifest::load` and the compat shim instead.
pub fn read_package(dir: &Path) -> Result<PackageInfo> {
    let manifest_path = dir.join("manifest.json");
    let text = fs::read_to_string(&manifest_path)
        .with_context(|| format!("reading {manifest_path:?}"))?;
    let j = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {manifest_path:?}: {e}"))?;
    match j.get("package") {
        None | Some(Json::Null) => bail!(
            "{dir:?} has a legacy manifest (no package block) — run `qtx pack --dir {}` to \
             package it",
            dir.display()
        ),
        Some(p) => {
            PackageInfo::from_json(p).with_context(|| format!("package block of {manifest_path:?}"))
        }
    }
}

/// Full content verification of a packaged dir: every entry must exist
/// with the exact recorded size and sha256. Returns the verified info;
/// any deviation is a descriptive error naming the entry.
pub fn verify_dir(dir: &Path) -> Result<PackageInfo> {
    let info = read_package(dir)?;
    for e in &info.entries {
        let path = dir.join(&e.path);
        if !path.is_file() {
            bail!("entry {:?} is missing from {dir:?}", e.path);
        }
        let (sha, bytes) = sha256_file(&path)?;
        if bytes != e.bytes {
            bail!(
                "entry {:?} is truncated or resized: {} bytes on disk, {} in the manifest",
                e.path,
                bytes,
                e.bytes
            );
        }
        if sha != e.sha256 {
            bail!(
                "entry {:?} fails its checksum: sha256 {} on disk, {} in the manifest",
                e.path,
                &sha[..SHORT_HEX],
                &e.sha256[..SHORT_HEX]
            );
        }
    }
    Ok(info)
}

// ---- doctor ---------------------------------------------------------------

/// Diagnosis severity, ordered: `Ok < Fixable < Fail`. CLI exit codes
/// follow the variant index (0 / 1 / 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum DoctorVerdict {
    Ok,
    Fixable,
    Fail,
}

/// `qtx doctor` result: the worst verdict plus one human line per check.
#[derive(Debug)]
pub struct DoctorReport {
    pub verdict: DoctorVerdict,
    pub notes: Vec<String>,
}

impl DoctorReport {
    fn note(&mut self, verdict: DoctorVerdict, msg: String) {
        self.verdict = self.verdict.max(verdict);
        self.notes.push(msg);
    }
}

/// Diagnose an artifact dir against this binary's required schema.
/// Never errors: I/O and parse failures are `Fail` notes.
pub fn doctor(dir: &Path) -> DoctorReport {
    let mut report = DoctorReport { verdict: DoctorVerdict::Ok, notes: Vec::new() };

    // Crashed-install leftovers live next to the dir, not inside it.
    if let (Some(parent), Some(name)) = (dir.parent(), dir.file_name()) {
        let name = name.to_string_lossy();
        let staging = parent.join(format!(".staging-{name}"));
        if staging.exists() {
            report.note(
                DoctorVerdict::Fixable,
                format!("leftover staging dir {staging:?} from a crashed install — remove it"),
            );
        }
        let lock = parent.join(format!(".{name}.install.lock"));
        if lock.exists() {
            report.note(
                DoctorVerdict::Fixable,
                format!("stale install lockfile {lock:?} — remove it if no install is running"),
            );
        }
    }

    let manifest_path = dir.join("manifest.json");
    let text = match fs::read_to_string(&manifest_path) {
        Ok(t) => t,
        Err(e) => {
            report.note(DoctorVerdict::Fail, format!("cannot read {manifest_path:?}: {e}"));
            return report;
        }
    };
    let j = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            report.note(DoctorVerdict::Fail, format!("cannot parse {manifest_path:?}: {e}"));
            return report;
        }
    };
    let pkg = match j.get("package") {
        None | Some(Json::Null) => {
            report.note(
                DoctorVerdict::Fixable,
                "legacy manifest (no package block): loads read-only via the compat shim — \
                 run `qtx pack` to make it installable"
                    .to_string(),
            );
            return report;
        }
        Some(p) => match PackageInfo::from_json(p) {
            Ok(info) => info,
            Err(e) => {
                report.note(DoctorVerdict::Fail, format!("package block rejected: {e:#}"));
                return report;
            }
        },
    };

    let mut bad = 0usize;
    for e in &pkg.entries {
        let path = dir.join(&e.path);
        if !path.is_file() {
            report.note(DoctorVerdict::Fail, format!("missing entry {:?}", e.path));
            bad += 1;
            continue;
        }
        match sha256_file(&path) {
            Err(err) => {
                report.note(DoctorVerdict::Fail, format!("unreadable entry {:?}: {err:#}", e.path));
                bad += 1;
            }
            Ok((_, bytes)) if bytes != e.bytes => {
                report.note(
                    DoctorVerdict::Fail,
                    format!(
                        "entry {:?} truncated or resized ({bytes} bytes on disk, {} expected)",
                        e.path, e.bytes
                    ),
                );
                bad += 1;
            }
            Ok((sha, _)) if sha != e.sha256 => {
                report.note(
                    DoctorVerdict::Fail,
                    format!(
                        "entry {:?} fails its checksum ({} on disk, {} expected)",
                        e.path,
                        &sha[..SHORT_HEX],
                        &e.sha256[..SHORT_HEX]
                    ),
                );
                bad += 1;
            }
            Ok(_) => {}
        }
    }
    if bad == 0 {
        report.notes.push(format!(
            "package schema {}, install_id {}, {} entries / {} bytes verified ({} · {})",
            pkg.schema,
            pkg.install_id,
            pkg.entries.len(),
            pkg.payload_bytes(),
            pkg.provenance.config,
            pkg.provenance.variant,
        ));
    }
    report
}

// ---- atomic install -------------------------------------------------------

/// An install staged but not yet committed. Holds the staging dir and
/// lockfile paths; dropping it WITHOUT [`commit`] or [`abort`] models a
/// crashed install (leftovers stay on disk for `doctor` to flag).
#[derive(Debug)]
pub struct StagedInstall {
    pub staging: PathBuf,
    pub dest: PathBuf,
    pub lock: PathBuf,
    pub info: PackageInfo,
}

fn dest_parts(dest: &Path) -> Result<(PathBuf, String)> {
    let name = dest
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .with_context(|| format!("install destination {dest:?} has no directory name"))?;
    let parent = match dest.parent() {
        Some(p) if p.as_os_str().is_empty() => PathBuf::from("."),
        Some(p) => p.to_path_buf(),
        None => PathBuf::from("."),
    };
    Ok((parent, name))
}

/// Stage `src` for installation at `dest`: verify the source package,
/// take the lockfile, copy manifest + entries into `.staging-<name>`,
/// and re-checksum every staged copy. The destination is untouched.
pub fn stage(src: &Path, dest: &Path) -> Result<StagedInstall> {
    let info = verify_dir(src).with_context(|| format!("source artifact {src:?}"))?;
    let (parent, name) = dest_parts(dest)?;
    fs::create_dir_all(&parent).with_context(|| format!("creating {parent:?}"))?;

    let lock = parent.join(format!(".{name}.install.lock"));
    fs::File::options().write(true).create_new(true).open(&lock).with_context(|| {
        format!(
            "taking install lock {lock:?} — another install is running, or a crashed one \
             left the lock behind (run `qtx doctor` and remove it)"
        )
    })?;

    let staging = parent.join(format!(".staging-{name}"));
    let staged = (|| -> Result<()> {
        if staging.exists() {
            bail!(
                "leftover staging dir {staging:?} from a crashed install — remove it and retry"
            );
        }
        fs::create_dir_all(&staging)?;
        fs::copy(src.join("manifest.json"), staging.join("manifest.json"))
            .context("copying manifest.json")?;
        for e in &info.entries {
            let to = staging.join(&e.path);
            if let Some(dir) = to.parent() {
                fs::create_dir_all(dir)?;
            }
            fs::copy(src.join(&e.path), &to)
                .with_context(|| format!("copying entry {:?}", e.path))?;
            let (sha, bytes) = sha256_file(&to)?;
            if bytes != e.bytes || sha != e.sha256 {
                bail!("staged copy of {:?} fails its checksum — unreliable disk?", e.path);
            }
        }
        Ok(())
    })();
    if let Err(e) = staged {
        let _ = fs::remove_file(&lock);
        return Err(e);
    }
    Ok(StagedInstall { staging, dest: dest.to_path_buf(), lock, info })
}

/// Commit a staged install: one `rename(2)` of the staging dir onto the
/// destination is the only point where `dest` changes. An existing
/// destination is parked aside first and deleted after the swap.
pub fn commit(staged: &StagedInstall) -> Result<()> {
    let (parent, name) = dest_parts(&staged.dest)?;
    let previous = parent.join(format!(".previous-{name}"));
    if previous.exists() {
        fs::remove_dir_all(&previous).with_context(|| format!("clearing {previous:?}"))?;
    }
    let had_previous = staged.dest.exists();
    if had_previous {
        fs::rename(&staged.dest, &previous)
            .with_context(|| format!("parking old {:?}", staged.dest))?;
    }
    if let Err(e) = fs::rename(&staged.staging, &staged.dest) {
        // Roll the old dir back so a failed commit still leaves a
        // working destination.
        if had_previous {
            let _ = fs::rename(&previous, &staged.dest);
        }
        return Err(e).with_context(|| {
            format!("renaming {:?} -> {:?}", staged.staging, staged.dest)
        });
    }
    if had_previous {
        let _ = fs::remove_dir_all(&previous);
    }
    fs::remove_file(&staged.lock).with_context(|| format!("releasing {:?}", staged.lock))?;
    Ok(())
}

/// Best-effort cleanup of an install that will not be committed.
pub fn abort(staged: &StagedInstall) {
    let _ = fs::remove_dir_all(&staged.staging);
    let _ = fs::remove_file(&staged.lock);
}

/// `stage` + `commit` with cleanup on failure. Returns the installed
/// package info.
pub fn install(src: &Path, dest: &Path) -> Result<PackageInfo> {
    let staged = stage(src, dest)?;
    if let Err(e) = commit(&staged) {
        abort(&staged);
        return Err(e);
    }
    Ok(staged.info)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("qtx-package-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    /// A minimal but structurally real artifact dir: manifest + two
    /// payload files.
    fn fake_artifact(root: &Path, name: &str) -> PathBuf {
        let dir = root.join(name);
        fs::create_dir_all(&dir).unwrap();
        fs::write(dir.join("init.hlo.txt"), b"HloModule init\nROOT r = f32[] constant(0)\n")
            .unwrap();
        fs::write(dir.join("weights.bin"), [7u8; 300]).unwrap();
        fs::write(
            dir.join("manifest.json"),
            r#"{"version":5,"fingerprint":"fp123","config":{"name":"c","attention":"clipped_softmax","use_gate":true},"quant_points":["embed","L0.q"]}"#,
        )
        .unwrap();
        dir
    }

    #[test]
    fn sha256_standard_vectors() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
        // One million 'a' bytes exercises multi-block streaming.
        let mut h = Sha256::new();
        let chunk = [b'a'; 997]; // deliberately not 64-aligned
        let mut left = 1_000_000usize;
        while left > 0 {
            let n = left.min(chunk.len());
            h.update(&chunk[..n]);
            left -= n;
        }
        assert_eq!(
            h.finish_hex(),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn pack_then_verify_roundtrips() {
        let root = tmpdir("pack");
        let dir = fake_artifact(&root, "c");
        let info = pack(&dir).unwrap();
        assert_eq!(info.schema, PACKAGE_SCHEMA);
        assert_eq!(info.entries.len(), 2);
        assert_eq!(info.entries[0].path, "init.hlo.txt");
        assert_eq!(info.entries[0].kind, "program");
        assert_eq!(info.entries[1].path, "weights.bin");
        assert_eq!(info.entries[1].kind, "data");
        assert_eq!(info.provenance.config, "c");
        assert_eq!(info.provenance.variant, "clipped_softmax+gate");
        assert_eq!(info.provenance.fingerprint, "fp123");
        assert_eq!(info.install_id.len(), 16);

        let verified = verify_dir(&dir).unwrap();
        assert_eq!(verified, info);
        // Repacking an unchanged dir is a fixpoint (same install id).
        let again = pack(&dir).unwrap();
        assert_eq!(again.install_id, info.install_id);

        let report = doctor(&dir);
        assert_eq!(report.verdict, DoctorVerdict::Ok, "{:?}", report.notes);
    }

    #[test]
    fn corrupted_byte_fails_closed() {
        let root = tmpdir("corrupt");
        let dir = fake_artifact(&root, "c");
        pack(&dir).unwrap();
        let mut bytes = fs::read(dir.join("weights.bin")).unwrap();
        bytes[17] ^= 0xFF;
        fs::write(dir.join("weights.bin"), bytes).unwrap();

        let err = verify_dir(&dir).unwrap_err().to_string();
        assert!(err.contains("weights.bin") && err.contains("checksum"), "{err}");
        let report = doctor(&dir);
        assert_eq!(report.verdict, DoctorVerdict::Fail);
        assert!(report.notes.iter().any(|n| n.contains("weights.bin")), "{:?}", report.notes);
    }

    #[test]
    fn truncated_entry_fails_closed() {
        let root = tmpdir("trunc");
        let dir = fake_artifact(&root, "c");
        pack(&dir).unwrap();
        fs::write(dir.join("weights.bin"), [7u8; 100]).unwrap();
        let err = verify_dir(&dir).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");

        fs::remove_file(dir.join("weights.bin")).unwrap();
        let err = verify_dir(&dir).unwrap_err().to_string();
        assert!(err.contains("missing"), "{err}");
        assert_eq!(doctor(&dir).verdict, DoctorVerdict::Fail);
    }

    #[test]
    fn unknown_schema_fails_closed() {
        let root = tmpdir("schema");
        let dir = fake_artifact(&root, "c");
        pack(&dir).unwrap();
        let text = fs::read_to_string(dir.join("manifest.json")).unwrap();
        let bumped = text.replace(
            &format!("\"schema\":{PACKAGE_SCHEMA}"),
            &format!("\"schema\":{}", PACKAGE_SCHEMA + 7),
        );
        assert_ne!(text, bumped, "schema key must be present to rewrite");
        fs::write(dir.join("manifest.json"), bumped).unwrap();

        let err = verify_dir(&dir).unwrap_err();
        let chain = format!("{err:#}");
        assert!(
            chain.contains(&format!("unsupported package schema {}", PACKAGE_SCHEMA + 7)),
            "{chain}"
        );
        assert!(chain.contains(&format!("supports schema {PACKAGE_SCHEMA}")), "{chain}");
        assert_eq!(doctor(&dir).verdict, DoctorVerdict::Fail);
    }

    #[test]
    fn duplicate_entry_paths_fail_closed() {
        let j = Json::parse(&format!(
            r#"{{"schema":{PACKAGE_SCHEMA},"install_id":"x","entries":[
                {{"path":"a.bin","kind":"data","bytes":1,"sha256":"{0}"}},
                {{"path":"a.bin","kind":"data","bytes":1,"sha256":"{0}"}}],
               "provenance":{{"fingerprint":"","config":"c","variant":"softmax",
                 "calibration_id":"","toolchain":"t"}}}}"#,
            sha256_hex(b"x")
        ))
        .unwrap();
        let err = PackageInfo::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("duplicate package entry path") && err.contains("a.bin"), "{err}");
    }

    #[test]
    fn legacy_dir_is_fixable_not_broken() {
        let root = tmpdir("legacy");
        let dir = fake_artifact(&root, "c");
        // Never packed: read_package refuses, doctor says fixable.
        let err = read_package(&dir).unwrap_err().to_string();
        assert!(err.contains("legacy manifest") && err.contains("qtx pack"), "{err}");
        let report = doctor(&dir);
        assert_eq!(report.verdict, DoctorVerdict::Fixable);
        assert!(report.notes.iter().any(|n| n.contains("compat shim")), "{:?}", report.notes);
    }

    #[test]
    fn install_roundtrip_and_lock_release() {
        let root = tmpdir("install");
        let src = fake_artifact(&root, "src");
        pack(&src).unwrap();
        let dest = root.join("installed/c");
        let info = install(&src, &dest).unwrap();
        assert_eq!(verify_dir(&dest).unwrap(), info);
        // No leftovers: lock released, staging renamed away.
        assert!(!root.join("installed/.staging-c").exists());
        assert!(!root.join("installed/.c.install.lock").exists());
        assert_eq!(doctor(&dest).verdict, DoctorVerdict::Ok);

        // Re-install over the existing dir replaces it atomically.
        fs::write(src.join("weights.bin"), [9u8; 300]).unwrap();
        pack(&src).unwrap();
        let info2 = install(&src, &dest).unwrap();
        assert_ne!(info.install_id, info2.install_id);
        assert_eq!(verify_dir(&dest).unwrap().install_id, info2.install_id);
    }

    #[test]
    fn crashed_install_leaves_old_dir_intact_and_doctor_flags_leftovers() {
        let root = tmpdir("crash");
        let src = fake_artifact(&root, "src");
        pack(&src).unwrap();
        let dest = root.join("installed/c");
        let first = install(&src, &dest).unwrap();

        // New payload staged but never committed: the "kill mid-install".
        fs::write(src.join("weights.bin"), [1u8; 300]).unwrap();
        pack(&src).unwrap();
        let staged = stage(&src, &dest).unwrap();
        assert!(staged.staging.exists() && staged.lock.exists());
        // Old destination is byte-for-byte intact.
        assert_eq!(verify_dir(&dest).unwrap().install_id, first.install_id);

        // Doctor flags the leftovers as fixable, not broken.
        let report = doctor(&dest);
        assert_eq!(report.verdict, DoctorVerdict::Fixable, "{:?}", report.notes);
        assert!(report.notes.iter().any(|n| n.contains("staging")), "{:?}", report.notes);
        assert!(report.notes.iter().any(|n| n.contains("lock")), "{:?}", report.notes);

        // A second install attempt refuses while the lock is held.
        let err = stage(&src, &dest).unwrap_err();
        assert!(format!("{err:#}").contains("install lock"), "{err:#}");

        // Abort cleans up; install then succeeds and swaps the payload.
        abort(&staged);
        assert_eq!(doctor(&dest).verdict, DoctorVerdict::Ok);
        let second = install(&src, &dest).unwrap();
        assert_ne!(second.install_id, first.install_id);
        assert_eq!(verify_dir(&dest).unwrap().install_id, second.install_id);
    }

    #[test]
    fn stage_refuses_unpacked_source() {
        let root = tmpdir("unpacked");
        let src = fake_artifact(&root, "src");
        let err = format!("{:#}", stage(&src, &root.join("d")).unwrap_err());
        assert!(err.contains("legacy manifest"), "{err}");
        // Refusal must not leave a lock behind.
        assert!(!root.join(".d.install.lock").exists());
    }
}
