//! Fleet-level e2e tests of `qtx route` over real TCP: router + N mock
//! `qtx serve` replicas, with the deterministic fault harness
//! (`FaultSpec`) standing in for real crashes. Tier-1: no artifacts, no
//! PJRT, no sleeps-as-synchronization (every wait polls an observable).
//!
//! The headline scenario is the ISSUE's acceptance drill: kill one of
//! three replicas mid-run and require *zero lost score requests* (every
//! response a 200 or a deliberate shed — never a 502/504), a
//! distinguishable `replica lost` 503 for decode sessions pinned to the
//! dead replica, and the replica rejoining the rotation after a
//! half-open probe once it comes back.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use qtx::serve::batcher::{BatchPolicy, BatcherConfig};
use qtx::serve::engine::{EngineFactory, MockEngine, ScoreEngine};
use qtx::serve::fault::FaultSpec;
use qtx::serve::loadgen::{self, LoadgenConfig};
use qtx::serve::obs::TraceConfig;
use qtx::serve::protocol::{GenerateRequest, ScoreRequest};
use qtx::serve::route::{Router, RouterConfig};
use qtx::serve::server::{Client, EngineInfo, Server, ServerConfig};
use qtx::serve::stats::EngineMem;
use qtx::util::json::Json;

const SEQ_LEN: usize = 512;
const MODEL_BATCH: usize = 8;

fn mock_factory(step_cost: Duration) -> EngineFactory {
    Arc::new(move || {
        let mut e = MockEngine::new(MODEL_BATCH, SEQ_LEN);
        e.batch_cost = Duration::ZERO;
        e.step_cost = step_cost;
        Ok(Box::new(e) as Box<dyn ScoreEngine>)
    })
}

/// One continuous-mode mock replica. `port` 0 for ephemeral; an explicit
/// port models restarting a crashed replica at its old address.
fn start_replica(port: u16, fault: FaultSpec, step_cost: Duration) -> Server {
    let probe = MockEngine::new(MODEL_BATCH, SEQ_LEN);
    let cfg = ServerConfig {
        host: "127.0.0.1".into(),
        port,
        max_connections: 64,
        engines: 1,
        policy: BatchPolicy::Continuous,
        batcher: BatcherConfig {
            max_batch: MODEL_BATCH,
            max_wait: Duration::from_millis(5),
            queue_cap: 128,
        },
        admit_window: Duration::ZERO,
        read_timeout: Duration::from_secs(60),
        request_timeout: Duration::from_secs(30),
        trace: TraceConfig::default(),
        fault,
    };
    let info = EngineInfo {
        seq_len: SEQ_LEN,
        max_batch: MODEL_BATCH,
        vocab: 1024,
        causal: probe.causal,
        decode: true,
        describe: probe.describe(),
        mem: EngineMem::default(),
        gemm_threads: 1,
    };
    let s = Server::start(cfg, info, mock_factory(step_cost)).unwrap();
    s.wait_ready(Duration::from_secs(10)).unwrap();
    s
}

/// Router with test-speed probe cadence (the defaults are tuned for
/// production loopback, not for a test that waits out three eject
/// cycles).
fn start_router(backends: Vec<String>) -> Router {
    Router::start(RouterConfig {
        backends,
        probe_interval: Duration::from_millis(25),
        probe_timeout: Duration::from_millis(250),
        eject_after: 2,
        halfopen_interval: Duration::from_millis(50),
        retry_max: 3,
        retry_backoff: Duration::from_millis(5),
        ..RouterConfig::default()
    })
    .unwrap()
}

fn get_json(addr: &str, path: &str) -> Json {
    let mut c = Client::connect(addr, Duration::from_secs(5)).unwrap();
    c.get_json(path).unwrap()
}

fn num(j: &Json, dotted: &str) -> f64 {
    let mut cur = j;
    for part in dotted.split('.') {
        cur = cur.req(part).unwrap_or_else(|e| panic!("{dotted}: {e}"));
    }
    cur.as_f64().unwrap_or_else(|| panic!("{dotted} not a number"))
}

/// Poll the router's `/statz` until `pred` holds (or fail loudly with the
/// last snapshot). Replica health converges via the probe thread, so
/// tests wait on the census instead of sleeping a guessed interval.
fn wait_statz(addr: &str, what: &str, timeout: Duration, pred: impl Fn(&Json) -> bool) -> Json {
    let t0 = Instant::now();
    loop {
        let statz = get_json(addr, "/statz");
        if pred(&statz) {
            return statz;
        }
        if t0.elapsed() > timeout {
            panic!("timed out waiting for {what}; last /statz: {statz}");
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// The acceptance drill: three replicas, one rigged to kill its
/// front-end after 5 dispatches, a closed-loop score run through the
/// router across the crash. Every score request must land a 200 — the
/// kill costs retries, never responses — and the fleet census must track
/// the ejection.
#[test]
fn scores_survive_replica_kill_mid_run() {
    let healthy0 = start_replica(0, FaultSpec::default(), Duration::ZERO);
    let doomed = start_replica(0, FaultSpec::parse("kill-after:5").unwrap(), Duration::ZERO);
    let healthy1 = start_replica(0, FaultSpec::default(), Duration::ZERO);
    let backends =
        vec![healthy0.addr().to_string(), doomed.addr().to_string(), healthy1.addr().to_string()];
    let router = start_router(backends);
    assert!(router.wait_ready(Duration::from_secs(5)), "no replica came up");
    let addr = router.addr().to_string();

    // 120 requests across 4 closed-loop clients; the kill trips on the
    // doomed replica's 5th dispatch, well inside the run.
    let report = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        clients: 4,
        requests_per_client: 30,
        vocab: 1024,
        seq_len: 64,
        seed: 7,
        timeout: Duration::from_secs(10),
        open_rate_rps: None,
        gen: None,
    })
    .unwrap();
    assert_eq!(
        report.errors, 0,
        "score requests were lost across the kill: {:?}",
        report.errors_by_cause
    );
    assert_eq!(report.ok, 120);

    // The router retried the requests the kill interrupted, silently.
    let statz = wait_statz(&addr, "doomed replica ejection", Duration::from_secs(5), |s| {
        num(s, "route.replicas.ejected") == 1.0
    });
    assert_eq!(num(&statz, "route.replicas.total"), 3.0);
    assert_eq!(num(&statz, "route.replicas.up"), 2.0);
    assert_eq!(num(&statz, "route.requests.total"), 120.0);
    assert_eq!(num(&statz, "route.requests.ok"), 120.0);
    assert!(num(&statz, "route.requests.retries") >= 1.0, "kill should have cost retries");
    assert_eq!(num(&statz, "route.requests.bad_gateway"), 0.0);
    assert_eq!(num(&statz, "route.requests.timeouts"), 0.0);
    assert_eq!(num(&statz, "route.latency.count"), 120.0);

    router.stop();
    doomed.stop();
    healthy0.stop();
    healthy1.stop();
}

/// An ejected replica rejoins through the half-open probe with no router
/// intervention. The "crashed" replica is modeled as a reserved port with
/// nothing listening (probes see connection-refused, exactly like a dead
/// process); starting a fresh server on that port is the recovery —
/// binding the same port a killed server's sockets still hold in
/// TIME_WAIT would need SO_REUSEADDR, which std's listener doesn't set.
#[test]
fn ejected_replica_rejoins_after_halfopen_probe() {
    let live = start_replica(0, FaultSpec::default(), Duration::ZERO);
    // Reserve a port for the not-yet-started replica, then free it.
    let reserved = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap()
    };
    let router = start_router(vec![live.addr().to_string(), reserved.to_string()]);
    assert!(router.wait_ready(Duration::from_secs(5)));
    let addr = router.addr().to_string();

    // The dead address ejects after `eject_after` refused probes.
    wait_statz(&addr, "dead replica ejection", Duration::from_secs(5), |s| {
        num(s, "route.replicas.ejected") == 1.0 && num(s, "route.replicas.up") == 1.0
    });

    // Recovery: the replica comes up on its advertised port. Only the
    // half-open re-probe can fold it back in.
    let revived = start_replica(reserved.port(), FaultSpec::default(), Duration::ZERO);
    let statz = wait_statz(&addr, "replica rejoin", Duration::from_secs(5), |s| {
        num(s, "route.replicas.up") == 2.0
    });
    assert_eq!(num(&statz, "route.replicas.ejected"), 0.0);

    // The rejoined fleet serves a burst with zero errors.
    let report = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        clients: 2,
        requests_per_client: 8,
        vocab: 1024,
        seq_len: 64,
        seed: 8,
        timeout: Duration::from_secs(10),
        open_rate_rps: None,
        gen: None,
    })
    .unwrap();
    assert_eq!(report.errors, 0, "rejoined fleet dropped requests: {:?}", report.errors_by_cause);

    router.stop();
    revived.stop();
    live.stop();
}

/// Decode sessions are sticky — a replica dying mid-generation cannot be
/// silently retried (the KV cache died with it). The client must see a
/// *distinguishable* 503 (`replica lost`), and once the fleet is empty,
/// *new* requests get the shed contract (503 + `Retry-After`) with a
/// different message — lost state vs. no capacity are different events.
#[test]
fn decode_session_on_dead_replica_gets_distinguishable_503() {
    // ~10 ms per decode step keeps the session alive long enough to kill
    // the replica under it.
    let backend = start_replica(0, FaultSpec::default(), Duration::from_millis(10));
    let router = start_router(vec![backend.addr().to_string()]);
    assert!(router.wait_ready(Duration::from_secs(5)));
    let addr = router.addr().to_string();

    let gen_addr = addr.clone();
    let gen = std::thread::spawn(move || {
        let mut c = Client::connect(&gen_addr, Duration::from_secs(30)).unwrap();
        let req = GenerateRequest::greedy(Some("doomed-session".into()), vec![1, 2, 3], 400);
        c.request("POST", "/v1/generate", Some(&req.to_json())).unwrap()
    });

    // Wait until the session's proxied connection is actually open, then
    // pull the replica out from under it.
    wait_statz(&addr, "decode session in flight", Duration::from_secs(5), |s| {
        num(s, "route.connections.upstream") >= 1.0
    });
    backend.stop();

    let (status, body) = gen.join().unwrap();
    assert_eq!(status, 503, "lost decode session should 503: {body}");
    assert!(body.contains("replica lost"), "distinguishable error body, got: {body}");

    // Fleet is now empty. Once probes eject the dead replica, new
    // requests shed — different message, Retry-After header present.
    wait_statz(&addr, "dead replica ejection", Duration::from_secs(5), |s| {
        num(s, "route.replicas.ejected") == 1.0
    });
    let score = ScoreRequest { id: None, tokens: vec![1, 2, 3], targets: None }.to_json();
    let payload = score.to_string();
    let mut raw = TcpStream::connect(&addr).unwrap();
    write!(
        raw,
        "POST /v1/score HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{}",
        payload.len(),
        payload
    )
    .unwrap();
    let mut resp = String::new();
    raw.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.1 503 "), "shed status, got: {resp}");
    assert!(resp.contains("Retry-After: 1"), "shed carries Retry-After: {resp}");
    assert!(resp.contains("no replicas available"), "shed message: {resp}");

    let statz = get_json(&addr, "/statz");
    assert_eq!(num(&statz, "route.requests.replica_lost"), 1.0);
    assert!(num(&statz, "route.requests.shed") >= 1.0);

    router.stop();
}

/// Router `/healthz` is the same contract `qtx serve` exposes (loadgen
/// probes it blind): `ready` flips on the first Up replica, and the
/// model limits are mirrored from the fleet so clients can size
/// requests without knowing a replica address.
#[test]
fn router_healthz_mirrors_fleet_and_reports_starting() {
    // A listener that never answers HTTP: probes fail, nothing comes Up.
    let dead = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let router = Router::start(RouterConfig {
        backends: vec![dead.local_addr().unwrap().to_string()],
        probe_interval: Duration::from_millis(25),
        probe_timeout: Duration::from_millis(100),
        ..RouterConfig::default()
    })
    .unwrap();
    let addr = router.addr().to_string();
    let mut c = Client::connect(&addr, Duration::from_secs(5)).unwrap();
    let (status, body) = c.request("GET", "/healthz", None).unwrap();
    assert_eq!(status, 503);
    let doc = Json::parse(&body).unwrap();
    assert_eq!(doc.req("status").unwrap().as_str(), Some("starting"));
    assert_eq!(doc.req("ready").unwrap().as_bool(), Some(false));
    assert_eq!(doc.req("role").unwrap().as_str(), Some("router"));
    assert_eq!(doc.req("replicas").unwrap().as_usize(), Some(1));
    assert_eq!(doc.req("replicas_up").unwrap().as_usize(), Some(0));
    drop(c);
    router.stop();

    // With a live replica the router is ready and mirrors its limits.
    let backend = start_replica(0, FaultSpec::default(), Duration::ZERO);
    let router = start_router(vec![backend.addr().to_string()]);
    assert!(router.wait_ready(Duration::from_secs(5)));
    let addr = router.addr().to_string();
    let mut c = Client::connect(&addr, Duration::from_secs(5)).unwrap();
    let (status, body) = c.request("GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    let doc = Json::parse(&body).unwrap();
    assert_eq!(doc.req("ready").unwrap().as_bool(), Some(true));
    assert_eq!(doc.req("seq_len").unwrap().as_usize(), Some(SEQ_LEN));
    assert_eq!(doc.req("max_batch").unwrap().as_usize(), Some(MODEL_BATCH));
    assert_eq!(doc.req("vocab").unwrap().as_usize(), Some(1024));
    // Unknown paths / methods follow the serve conventions.
    let (status, _) = c.request("GET", "/nope", None).unwrap();
    assert_eq!(status, 404);
    let (status, _) = c.request("PUT", "/v1/score", Some(&Json::obj(vec![]))).unwrap();
    assert_eq!(status, 405);
    drop(c);
    router.stop();
    backend.stop();
}

fn leaf_paths(j: &Json, prefix: &str, out: &mut Vec<String>) {
    if prefix == "replica_detail" {
        return; // JSON-only per-replica rows, excluded from the contract
    }
    match j {
        Json::Obj(kv) => {
            for (k, v) in kv {
                let p =
                    if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                leaf_paths(v, &p, out);
            }
        }
        _ => out.push(prefix.to_string()),
    }
}

fn documented_list(marker: &str) -> Vec<String> {
    let api = std::fs::read_to_string(concat!(env!("CARGO_MANIFEST_DIR"), "/docs/API.md"))
        .expect("docs/API.md exists");
    let begin = api
        .find(&format!("<!-- {marker}:begin -->"))
        .unwrap_or_else(|| panic!("docs/API.md has a {marker}:begin marker"));
    let end = api
        .find(&format!("<!-- {marker}:end -->"))
        .unwrap_or_else(|| panic!("docs/API.md has a {marker}:end marker"));
    let mut out: Vec<String> = api[begin..end]
        .lines()
        .filter_map(|l| l.trim().strip_prefix("- `")?.strip_suffix('`').map(str::to_string))
        .collect();
    out.sort();
    assert!(!out.is_empty(), "no entries documented between the {marker} markers");
    out
}

/// Bidirectional doc conformance for the router's `/statz` and
/// `/metricz`, in the same style as the serve-side contract tests: the
/// live surfaces must expose exactly the keys/families docs/API.md
/// lists between the route markers.
#[test]
fn route_statz_and_metricz_match_documented_contract() {
    let backend = start_replica(0, FaultSpec::default(), Duration::ZERO);
    let router = start_router(vec![backend.addr().to_string()]);
    assert!(router.wait_ready(Duration::from_secs(5)));
    let addr = router.addr().to_string();
    let mut c = Client::connect(&addr, Duration::from_secs(5)).unwrap();
    let req = ScoreRequest { id: None, tokens: vec![1, 2, 3], targets: None };
    let (status, _) = c.request("POST", "/v1/score", Some(&req.to_json())).unwrap();
    assert_eq!(status, 200, "one scored request fills the histograms");

    let statz = c.get_json("/statz").unwrap();
    let mut live = Vec::new();
    leaf_paths(&statz, "", &mut live);
    live.sort();
    assert_eq!(
        live,
        documented_list("route-statz-keys"),
        "live router /statz keys (left) diverge from docs/API.md route-statz-keys (right)"
    );

    let (status, text) = c.request("GET", "/metricz", None).unwrap();
    assert_eq!(status, 200);
    let mut families: Vec<String> = text
        .lines()
        .filter_map(|l| l.strip_prefix("# TYPE "))
        .filter_map(|l| l.split_whitespace().next().map(str::to_string))
        .collect();
    families.sort();
    families.dedup();
    assert_eq!(
        families,
        documented_list("route-metricz-names"),
        "live router /metricz families (left) diverge from docs/API.md route-metricz-names"
    );

    drop(c);
    router.stop();
    backend.stop();
}
