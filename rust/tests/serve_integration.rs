//! End-to-end tests of the serving subsystem over real TCP: server + HTTP
//! pool + dynamic batcher + engine pool + loadgen client, using the
//! deterministic mock engine so they run in plain `cargo test` with no
//! artifacts or PJRT runtime. The PJRT engine swaps in behind the same
//! `ScoreEngine` trait (`qtx serve` without `--mock`).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use qtx::infer::SampleParams;
use qtx::serve::batcher::{BatchPolicy, BatcherConfig};
use qtx::serve::engine::{EngineFactory, MockEngine, ScoreEngine, WeightHub};
use qtx::serve::loadgen::{self, GenLoad, LoadgenConfig};
use qtx::serve::obs::TraceConfig;
use qtx::serve::protocol::{GenerateRequest, GenerateResponse, ScoreRequest, ScoreResponse};
use qtx::serve::server::{AdminHooks, Client, EngineInfo, ReloadOutcome, Server, ServerConfig};
use qtx::serve::stats::{ArtifactId, EngineMem};
use qtx::util::json::Json;

const SEQ_LEN: usize = 32;
const MODEL_BATCH: usize = 8;

fn mock_factory(cost: Duration) -> EngineFactory {
    Arc::new(move || {
        let mut e = MockEngine::new(MODEL_BATCH, SEQ_LEN);
        e.batch_cost = cost;
        Ok(Box::new(e) as Box<dyn ScoreEngine>)
    })
}

fn start_server_timeouts(
    policy: BatchPolicy,
    max_wait_ms: u64,
    queue_cap: usize,
    max_connections: usize,
    cost: Duration,
    read_timeout: Duration,
) -> Server {
    let probe = MockEngine::new(MODEL_BATCH, SEQ_LEN);
    let cfg = ServerConfig {
        host: "127.0.0.1".into(),
        port: 0, // ephemeral
        max_connections,
        engines: 1,
        policy,
        batcher: BatcherConfig {
            max_batch: MODEL_BATCH,
            max_wait: Duration::from_millis(max_wait_ms),
            queue_cap,
        },
        admit_window: Duration::ZERO,
        read_timeout,
        request_timeout: Duration::from_secs(10),
        trace: TraceConfig::default(),
        fault: Default::default(),
    };
    let info = EngineInfo {
        seq_len: SEQ_LEN,
        max_batch: MODEL_BATCH,
        vocab: 1024,
        causal: probe.causal,
        decode: true,
        describe: probe.describe(),
        mem: EngineMem::default(),
        gemm_threads: 1,
    };
    let s = Server::start(cfg, info, mock_factory(cost)).unwrap();
    s.wait_ready(Duration::from_secs(10)).unwrap();
    s
}

fn start_server_with(
    policy: BatchPolicy,
    max_wait_ms: u64,
    queue_cap: usize,
    max_connections: usize,
    cost: Duration,
) -> Server {
    start_server_timeouts(
        policy,
        max_wait_ms,
        queue_cap,
        max_connections,
        cost,
        Duration::from_secs(60),
    )
}

fn start_server(max_wait_ms: u64, cost: Duration) -> Server {
    // The pre-existing tests exercise the PR-1 baseline path.
    start_server_with(BatchPolicy::Fixed, max_wait_ms, 128, 16, cost)
}

#[test]
fn score_roundtrip_and_health() {
    let server = start_server(2, Duration::ZERO);
    let addr = server.addr().to_string();
    let mut c = Client::connect(&addr, Duration::from_secs(5)).unwrap();

    let health = c.get_json("/healthz").unwrap();
    assert_eq!(health.req("status").unwrap().as_str(), Some("ok"));
    assert_eq!(health.req("seq_len").unwrap().as_usize(), Some(SEQ_LEN));
    assert_eq!(health.req("max_batch").unwrap().as_usize(), Some(MODEL_BATCH));

    let req = ScoreRequest { id: Some("t1".into()), tokens: vec![1, 2, 3, 4, 5], targets: None };
    let (status, body) = c.request("POST", "/v1/score", Some(&req.to_json())).unwrap();
    assert_eq!(status, 200, "{body}");
    let resp = ScoreResponse::parse(&body).unwrap();
    assert_eq!(resp.id.as_deref(), Some("t1"));
    // causal mock: 4 next-token positions scored
    assert_eq!(resp.row.count, 4.0);
    assert!(resp.row.nll > 0.0 && resp.ppl() > 1.0);
    assert!(resp.batch_size >= 1);

    // Determinism: same tokens, same score (keep-alive, same connection).
    let (_, body2) = c.request("POST", "/v1/score", Some(&req.to_json())).unwrap();
    let resp2 = ScoreResponse::parse(&body2).unwrap();
    assert_eq!(resp.row, resp2.row);

    drop(c); // free the keep-alive handler before joining the server
    server.stop();
}

#[test]
fn bad_requests_get_400_not_500() {
    let server = start_server(2, Duration::ZERO);
    let addr = server.addr().to_string();
    let mut c = Client::connect(&addr, Duration::from_secs(5)).unwrap();

    let too_long = format!(r#"{{"tokens":[{}]}}"#, vec!["7"; SEQ_LEN + 1].join(","));
    let cases: [(&str, &str); 4] = [
        ("{}", "missing tokens"),
        (r#"{"tokens":[1]}"#, "too short"),
        (r#"{"tokens":"zap"}"#, "wrong type"),
        (too_long.as_str(), "too long"),
    ];
    for (body, why) in cases {
        let j = Json::parse(body).unwrap();
        let (status, _) = c.request("POST", "/v1/score", Some(&j)).unwrap();
        assert_eq!(status, 400, "{why}");
    }
    // Unknown route and wrong method.
    let (status, _) = c.request("GET", "/nope", None).unwrap();
    assert_eq!(status, 404);
    let (status, _) = c.request("GET", "/v1/score", None).unwrap();
    assert_eq!(status, 405);

    drop(c);
    server.stop();
}

/// The acceptance loop: concurrent closed-loop clients through the HTTP
/// API; dynamic batching must demonstrably engage (fill ratio > 1).
#[test]
fn loadgen_roundtrip_batches_requests() {
    // A visible per-dispatch cost so requests pile up while a batch runs.
    let server = start_server(20, Duration::from_millis(4));
    let addr = server.addr().to_string();

    let report = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        clients: 4,
        requests_per_client: 40,
        vocab: 128,
        seq_len: 0, // probe /healthz
        seed: 7,
        timeout: Duration::from_secs(10),
        open_rate_rps: None,
        gen: None,
    })
    .unwrap();
    assert_eq!(report.ok, 160, "errors: {}", report.errors);
    assert_eq!(report.errors, 0);
    assert!(report.throughput_rps > 0.0);
    assert!(report.p99_ms >= report.p50_ms);

    let mut c = Client::connect(&addr, Duration::from_secs(5)).unwrap();
    let statz = c.get_json("/statz").unwrap();
    let batches = statz.req("batches").unwrap();
    let rows = batches.req("rows").unwrap().as_usize().unwrap();
    let total = batches.req("total").unwrap().as_usize().unwrap();
    let fill = batches.req("fill_ratio").unwrap().as_f64().unwrap();
    assert_eq!(rows, 160, "every request scored exactly once");
    assert!(total < rows, "some invocation carried >1 request (total={total})");
    assert!(fill > 1.0, "dynamic batching engaged (fill_ratio={fill})");
    assert_eq!(
        statz.req("requests").unwrap().req("ok").unwrap().as_usize(),
        Some(160)
    );

    drop(c);
    server.stop();
}

/// Backpressure: a tiny queue + slow engine sheds load with 503 instead of
/// queueing unboundedly.
#[test]
fn queue_full_returns_503() {
    let probe = MockEngine::new(1, SEQ_LEN);
    let cfg = ServerConfig {
        host: "127.0.0.1".into(),
        port: 0,
        max_connections: 16,
        engines: 1,
        policy: BatchPolicy::Fixed,
        batcher: BatcherConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            queue_cap: 1,
        },
        admit_window: Duration::ZERO,
        read_timeout: Duration::from_secs(60),
        request_timeout: Duration::from_secs(10),
        trace: TraceConfig::default(),
        fault: Default::default(),
    };
    let info = EngineInfo {
        seq_len: SEQ_LEN,
        max_batch: 1,
        vocab: 1024,
        causal: probe.causal,
        decode: true,
        describe: probe.describe(),
        mem: EngineMem::default(),
        gemm_threads: 1,
    };
    let server = Server::start(
        cfg,
        info,
        Arc::new(|| {
            let mut e = MockEngine::new(1, SEQ_LEN);
            e.batch_cost = Duration::from_millis(50);
            Ok(Box::new(e) as Box<dyn ScoreEngine>)
        }),
    )
    .unwrap();
    server.wait_ready(Duration::from_secs(10)).unwrap();
    let addr = server.addr().to_string();

    // Flood with more concurrent requests than queue+engine can hold.
    let mut handles = Vec::new();
    for i in 0..8 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr, Duration::from_secs(10)).unwrap();
            let req = ScoreRequest { id: None, tokens: vec![i, i + 1, i + 2], targets: None };
            let (status, _) = c.request("POST", "/v1/score", Some(&req.to_json())).unwrap();
            status
        }));
    }
    let statuses: Vec<u16> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(statuses.iter().any(|&s| s == 200), "{statuses:?}");
    assert!(
        statuses.iter().any(|&s| s == 503),
        "expected some load shedding, got {statuses:?}"
    );
    assert!(statuses.iter().all(|&s| s == 200 || s == 503), "{statuses:?}");

    server.stop();
}

/// Continuous mode serves the same API and exposes the slot census.
#[test]
fn continuous_mode_roundtrip_and_slot_census() {
    let server = start_server_with(
        BatchPolicy::Continuous,
        5, // max_wait is inert in continuous mode
        128,
        16,
        Duration::from_millis(2),
    );
    let addr = server.addr().to_string();
    let mut c = Client::connect(&addr, Duration::from_secs(5)).unwrap();

    let health = c.get_json("/healthz").unwrap();
    assert_eq!(health.req("batch_policy").unwrap().as_str(), Some("continuous"));

    let req = ScoreRequest { id: Some("s1".into()), tokens: vec![4, 5, 6], targets: None };
    let (status, body) = c.request("POST", "/v1/score", Some(&req.to_json())).unwrap();
    assert_eq!(status, 200, "{body}");
    let resp = ScoreResponse::parse(&body).unwrap();
    assert_eq!(resp.row.count, 2.0);
    // Scores are policy-invariant: the fixed-mode server gives the same row.
    let fixed = start_server(5, Duration::ZERO);
    let mut cf = Client::connect(&fixed.addr().to_string(), Duration::from_secs(5)).unwrap();
    let (_, fbody) = cf.request("POST", "/v1/score", Some(&req.to_json())).unwrap();
    assert_eq!(ScoreResponse::parse(&fbody).unwrap().row, resp.row);
    drop(cf);
    fixed.stop();

    let statz = c.get_json("/statz").unwrap();
    assert_eq!(statz.req("batch_policy").unwrap().as_str(), Some("continuous"));
    let slots = statz.req("slots").unwrap();
    assert_eq!(slots.req("total").unwrap().as_usize(), Some(MODEL_BATCH));
    let admission = statz.req("queue").unwrap().req("admission").unwrap();
    assert_eq!(admission.req("count").unwrap().as_usize(), Some(1));
    // Quiescent after the reply: every slot returns to free. The worker
    // releases its slots just *after* sending the reply, so poll briefly
    // rather than racing that hand-off.
    let mut free = 0;
    for _ in 0..50 {
        let statz = c.get_json("/statz").unwrap();
        free = statz.req("slots").unwrap().req("free").unwrap().as_usize().unwrap();
        if free == MODEL_BATCH {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(free, MODEL_BATCH, "slots did not return to free");

    drop(c);
    server.stop();
}

/// `POST /v1/generate` end-to-end over the continuous batcher: the served
/// continuation equals an offline greedy replay on the same (mock)
/// engine, repeats deterministically, and `/statz` grows a decode section.
#[test]
fn generate_roundtrip_matches_offline_decode() {
    let server = start_server_with(BatchPolicy::Continuous, 5, 128, 16, Duration::from_millis(1));
    let addr = server.addr().to_string();
    let mut c = Client::connect(&addr, Duration::from_secs(5)).unwrap();

    let req = GenerateRequest::greedy(Some("g1".into()), vec![3, 1, 4], 5);
    let (status, body) = c.request("POST", "/v1/generate", Some(&req.to_json())).unwrap();
    assert_eq!(status, 200, "{body}");
    let resp = GenerateResponse::parse(&body).unwrap();
    assert_eq!(resp.id.as_deref(), Some("g1"));
    assert_eq!(resp.tokens.len(), 5);
    assert_eq!(resp.prompt_len, 3);
    assert!(resp.prefill_ms >= 0.0 && resp.decode_ms >= 0.0);
    // Greedy requests echo no seed — the response shape predates sampling.
    assert_eq!(resp.seed, None);
    assert!(!body.contains("seed"), "greedy response must not grow a seed field: {body}");

    // Offline greedy replay on a fresh engine must agree exactly —
    // generation is a pure function of the prompt, not of slot/batching.
    let mut offline = MockEngine::new(MODEL_BATCH, SEQ_LEN);
    offline.step_cost = Duration::ZERO;
    let mut want = vec![offline.gen_prefill(0, &req.tokens, &SampleParams::greedy()).unwrap()];
    for _ in 1..5 {
        let last = *want.last().unwrap();
        want.push(offline.gen_step(0, last).unwrap());
    }
    assert_eq!(resp.tokens, want, "served generation != offline greedy decode");

    // Determinism through the server too.
    let (_, body2) = c.request("POST", "/v1/generate", Some(&req.to_json())).unwrap();
    assert_eq!(GenerateResponse::parse(&body2).unwrap().tokens, want);

    // Oversized sessions are rejected up front with 400.
    let too_big = GenerateRequest::greedy(None, vec![1; SEQ_LEN - 2], 8);
    let (status, _) = c.request("POST", "/v1/generate", Some(&too_big.to_json())).unwrap();
    assert_eq!(status, 400);

    let statz = c.get_json("/statz").unwrap();
    let decode = statz.req("decode").unwrap();
    assert_eq!(decode.req("sessions_total").unwrap().as_usize(), Some(2));
    assert_eq!(decode.req("sessions_active").unwrap().as_usize(), Some(0));
    assert_eq!(decode.req("tokens_total").unwrap().as_usize(), Some(10));
    assert!(decode.req("tokens_per_s").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(decode.req("step").unwrap().req("count").unwrap().as_usize(), Some(8));

    drop(c);
    server.stop();
}

/// The fixed micro-batcher has no persistent slots, so generation is
/// refused loudly (501), not silently mis-served.
#[test]
fn generate_rejected_on_fixed_policy() {
    let server = start_server(2, Duration::ZERO);
    let addr = server.addr().to_string();
    let mut c = Client::connect(&addr, Duration::from_secs(5)).unwrap();
    let req = GenerateRequest::greedy(None, vec![1, 2], 4);
    let (status, body) = c.request("POST", "/v1/generate", Some(&req.to_json())).unwrap();
    assert_eq!(status, 501, "{body}");
    assert!(body.contains("continuous"), "{body}");
    drop(c);
    server.stop();
}

/// The decode loadgen smoke CI runs in tier 1: `qtx loadgen --generate`
/// semantics against a MockEngine server — sessions interleave on slots,
/// every request resolves, token accounting matches.
#[test]
fn loadgen_generate_smoke() {
    let server = start_server_with(BatchPolicy::Continuous, 5, 128, 16, Duration::from_millis(1));
    let addr = server.addr().to_string();
    let report = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        clients: 4,
        requests_per_client: 8,
        vocab: 128,
        seq_len: 0, // probe /healthz
        seed: 9,
        timeout: Duration::from_secs(10),
        open_rate_rps: None,
        gen: Some(GenLoad::greedy(6, 0)),
    })
    .unwrap();
    assert_eq!(report.ok, 32, "errors: {}", report.errors);
    assert_eq!(report.errors, 0);
    assert_eq!(report.gen_tokens_total, 32 * 6, "every session decoded to max_new_tokens");
    assert!(report.gen_tokens_per_s > 0.0);

    let mut c = Client::connect(&addr, Duration::from_secs(5)).unwrap();
    let statz = c.get_json("/statz").unwrap();
    let decode = statz.req("decode").unwrap();
    assert_eq!(decode.req("sessions_total").unwrap().as_usize(), Some(32));
    assert_eq!(decode.req("tokens_total").unwrap().as_usize(), Some(32 * 6));
    // All slots back to free once the sessions drained.
    let slots = statz.req("slots").unwrap();
    assert_eq!(slots.req("generating").unwrap().as_usize(), Some(0));
    drop(c);
    server.stop();
}

/// HTTP/1.0 without `Connection: keep-alive` must default to close (RFC
/// 9112 §9.3) — the hand-rolled client is 1.1, so drive a raw socket.
#[test]
fn http10_defaults_to_connection_close() {
    let server = start_server(2, Duration::ZERO);
    let addr = server.addr();

    // Bare HTTP/1.0 request: one response, `Connection: close`, then EOF.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(b"GET /healthz HTTP/1.0\r\nHost: qtx\r\n\r\n").unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap(); // EOF only if the server closed
    assert!(buf.starts_with("HTTP/1.1 200"), "{buf}");
    assert!(buf.to_ascii_lowercase().contains("connection: close"), "{buf}");

    // Explicit keep-alive opt-in: the connection survives two requests.
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let req = b"GET /healthz HTTP/1.0\r\nHost: qtx\r\nConnection: keep-alive\r\n\r\n";
    s.write_all(req).unwrap();
    let first = read_one_response(&mut s);
    assert!(first.starts_with("HTTP/1.1 200"), "{first}");
    assert!(first.to_ascii_lowercase().contains("connection: keep-alive"), "{first}");
    s.write_all(req).unwrap();
    let second = read_one_response(&mut s);
    assert!(second.starts_with("HTTP/1.1 200"), "second request on kept-alive 1.0: {second}");

    server.stop();
}

/// Read exactly one HTTP response (head + Content-Length body) off a raw
/// socket, returning it as text.
fn read_one_response(s: &mut TcpStream) -> String {
    let mut buf = Vec::new();
    let mut byte = [0u8; 1];
    // Head: read to CRLFCRLF.
    while !buf.ends_with(b"\r\n\r\n") {
        match s.read(&mut byte) {
            Ok(1) => buf.push(byte[0]),
            other => panic!("connection ended mid-head: {other:?} after {buf:?}"),
        }
    }
    let head = String::from_utf8_lossy(&buf).to_string();
    let len: usize = head
        .to_ascii_lowercase()
        .lines()
        .find_map(|l| l.strip_prefix("content-length:").map(|v| v.trim().parse().unwrap()))
        .unwrap_or(0);
    let mut body = vec![0u8; len];
    s.read_exact(&mut body).unwrap();
    head + &String::from_utf8_lossy(&body)
}

/// A client that stalls mid-request (here: promising a body it never
/// sends) gets `408 Request Timeout` — not the silent close an *idle*
/// keep-alive connection correctly gets.
#[test]
fn stalled_mid_request_gets_408_idle_close_stays_silent() {
    let server = start_server_timeouts(
        BatchPolicy::Continuous,
        5,
        128,
        16,
        Duration::ZERO,
        Duration::from_millis(300), // short read timeout for the test
    );
    let addr = server.addr();

    // Stalled mid-body: head promises 64 bytes, sends 5, then stalls.
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.write_all(b"POST /v1/score HTTP/1.1\r\nContent-Length: 64\r\n\r\n{\"tok").unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    assert!(buf.starts_with("HTTP/1.1 408"), "stalled client should see 408, got: {buf:?}");

    // Stalled mid-head: same deal.
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.write_all(b"POST /v1/score HT").unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    assert!(buf.starts_with("HTTP/1.1 408"), "mid-head stall should see 408, got: {buf:?}");

    // Idle connection (zero bytes sent): silent close — any bytes here
    // would desynchronize a pipelining keep-alive client.
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    assert!(buf.is_empty(), "idle close must not write bytes, got {buf:?}");

    server.stop();
}

/// Doc conformance: `docs/API.md` lists every `/statz` key between the
/// `statz-keys` markers; a live snapshot must expose exactly that set —
/// a key the server drops fails the doc, a key the doc forgot fails the
/// server change that added it.
#[test]
fn statz_matches_documented_contract() {
    fn leaf_paths(j: &Json, prefix: &str, out: &mut Vec<String>) {
        match j {
            Json::Obj(kv) => {
                for (k, v) in kv {
                    let p = if prefix.is_empty() {
                        k.clone()
                    } else {
                        format!("{prefix}.{k}")
                    };
                    leaf_paths(v, &p, out);
                }
            }
            _ => out.push(prefix.to_string()),
        }
    }

    let api = std::fs::read_to_string(concat!(env!("CARGO_MANIFEST_DIR"), "/docs/API.md"))
        .expect("docs/API.md exists");
    let begin = api
        .find("<!-- statz-keys:begin -->")
        .expect("docs/API.md has a <!-- statz-keys:begin --> marker");
    let end = api.find("<!-- statz-keys:end -->").expect("statz-keys end marker");
    let mut documented: Vec<String> = api[begin..end]
        .lines()
        .filter_map(|l| l.trim().strip_prefix("- `")?.strip_suffix('`').map(str::to_string))
        .collect();
    documented.sort();
    assert!(!documented.is_empty(), "no keys documented between the markers");

    // Continuous mode exposes the full document (slot census included);
    // one scored request fills the histograms.
    let server = start_server_with(BatchPolicy::Continuous, 5, 128, 16, Duration::ZERO);
    let addr = server.addr().to_string();
    let mut c = Client::connect(&addr, Duration::from_secs(5)).unwrap();
    let req = ScoreRequest { id: None, tokens: vec![1, 2, 3], targets: None };
    let (status, _) = c.request("POST", "/v1/score", Some(&req.to_json())).unwrap();
    assert_eq!(status, 200);
    let statz = c.get_json("/statz").unwrap();
    let mut live = Vec::new();
    leaf_paths(&statz, "", &mut live);
    live.sort();

    assert_eq!(
        live, documented,
        "live /statz keys (left) diverge from docs/API.md statz-keys list (right)"
    );

    drop(c);
    server.stop();
}

/// Families named by `# TYPE` lines in a Prometheus exposition, sorted
/// and deduplicated.
fn metricz_families(text: &str) -> Vec<String> {
    let mut fams: Vec<String> = text
        .lines()
        .filter_map(|l| l.strip_prefix("# TYPE "))
        .filter_map(|l| l.split_whitespace().next().map(str::to_string))
        .collect();
    fams.sort();
    fams.dedup();
    fams
}

/// Doc conformance for `/metricz`, bidirectional like the `/statz` one:
/// docs/API.md lists every metric family between the `metricz-names`
/// markers; the live exposition must announce exactly that set via
/// `# TYPE` lines. Both surfaces render one shared registry, so a family
/// the server drops fails the doc and a family the doc forgot fails the
/// change that added it.
#[test]
fn metricz_matches_documented_contract() {
    let api = std::fs::read_to_string(concat!(env!("CARGO_MANIFEST_DIR"), "/docs/API.md"))
        .expect("docs/API.md exists");
    let begin = api
        .find("<!-- metricz-names:begin -->")
        .expect("docs/API.md has a <!-- metricz-names:begin --> marker");
    let end = api.find("<!-- metricz-names:end -->").expect("metricz-names end marker");
    let mut documented: Vec<String> = api[begin..end]
        .lines()
        .filter_map(|l| l.trim().strip_prefix("- `")?.strip_suffix('`').map(str::to_string))
        .collect();
    documented.sort();
    assert!(!documented.is_empty(), "no families documented between the markers");

    // Continuous mode exposes the full family set (slot census included).
    let server = start_server_with(BatchPolicy::Continuous, 5, 128, 16, Duration::ZERO);
    let mut c = Client::connect(&server.addr().to_string(), Duration::from_secs(5)).unwrap();
    let req = ScoreRequest { id: None, tokens: vec![1, 2, 3], targets: None };
    let (status, _) = c.request("POST", "/v1/score", Some(&req.to_json())).unwrap();
    assert_eq!(status, 200);
    let (status, text) = c.request("GET", "/metricz", None).unwrap();
    assert_eq!(status, 200);
    assert_eq!(
        metricz_families(&text),
        documented,
        "live /metricz families (left) diverge from docs/API.md metricz-names list (right)"
    );

    drop(c);
    server.stop();
}

/// `/metricz` is well-formed Prometheus text exposition, and its numbers
/// agree with the `/statz` snapshot it was rendered from: every sample
/// line parses, histogram buckets are cumulative and end in `+Inf`,
/// `_count` equals the `+Inf` bucket and the `/statz` count, counters
/// match the JSON surface.
#[test]
fn metricz_exposition_is_valid_and_consistent_with_statz() {
    let server = start_server_with(BatchPolicy::Continuous, 5, 128, 16, Duration::ZERO);
    let mut c = Client::connect(&server.addr().to_string(), Duration::from_secs(5)).unwrap();
    for i in 0..3 {
        let req = ScoreRequest { id: None, tokens: vec![i, i + 1, i + 2], targets: None };
        let (status, _) = c.request("POST", "/v1/score", Some(&req.to_json())).unwrap();
        assert_eq!(status, 200);
    }
    // Same keep-alive connection, no concurrent traffic: the two scrapes
    // see identical registry contents.
    let statz = c.get_json("/statz").unwrap();
    let (status, text) = c.request("GET", "/metricz", None).unwrap();
    assert_eq!(status, 200);

    // Line grammar: `# HELP|TYPE ...` comments or `name[{labels}] value`.
    for line in text.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name_part, value) =
            line.rsplit_once(' ').unwrap_or_else(|| panic!("no value on line {line:?}"));
        assert!(value.parse::<f64>().is_ok(), "unparseable value on {line:?}");
        let name = name_part.split('{').next().unwrap();
        assert!(name.starts_with("qtx_"), "unprefixed metric: {line:?}");
        assert!(
            name.chars().all(|ch| ch.is_ascii_alphanumeric() || ch == '_'),
            "bad metric name: {line:?}"
        );
        if name_part.contains('{') {
            assert!(name_part.ends_with('}'), "unterminated label set: {line:?}");
        }
    }

    // Histogram shape: cumulative monotone buckets, `+Inf` last and equal
    // to `_count`, `_count` equal to the JSON surface.
    let mut cum_prev = -1.0;
    let mut bucket_lines = 0;
    let mut inf_cum = None;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("qtx_latency_seconds_bucket{le=\"") {
            let (le, rest) = rest.split_once('"').expect("closing quote on le label");
            let cum: f64 = rest.trim_start_matches('}').trim().parse().unwrap();
            assert!(cum >= cum_prev, "buckets must be cumulative: {line}");
            cum_prev = cum;
            bucket_lines += 1;
            assert!(inf_cum.is_none(), "+Inf must be the last bucket");
            if le == "+Inf" {
                inf_cum = Some(cum);
            }
        }
    }
    assert!(bucket_lines > 10, "expected the full bucket table, got {bucket_lines} lines");
    let sample = |prefix: &str| -> f64 {
        let line = text
            .lines()
            .find(|l| l.starts_with(prefix) && !l.starts_with('#'))
            .unwrap_or_else(|| panic!("no {prefix} sample"));
        line.rsplit(' ').next().unwrap().parse().unwrap()
    };
    let count = sample("qtx_latency_seconds_count ");
    assert_eq!(Some(count), inf_cum, "+Inf bucket must equal _count");
    let statz_count = statz.req("latency").unwrap().req("count").unwrap().as_f64().unwrap();
    assert_eq!(count, statz_count);
    assert_eq!(count, 3.0);
    assert!(sample("qtx_latency_seconds_sum ") >= 0.0);
    // Counter + gauge spot-checks against the shared registry.
    assert_eq!(sample("qtx_requests_ok "), 3.0);
    assert_eq!(sample("qtx_slots_total "), MODEL_BATCH as f64);

    drop(c);
    server.stop();
}

/// `GET /debug/traces` end-to-end: one score and one generate request
/// leave two sealed traces whose spans cover the documented lifecycle
/// (read → parse → queue → claim → dispatch/engine_exec or prefill/step →
/// reply), sorted by start offset, newest trace first.
#[test]
fn debug_traces_record_request_lifecycle() {
    let server = start_server_with(BatchPolicy::Continuous, 5, 128, 16, Duration::ZERO);
    let mut c = Client::connect(&server.addr().to_string(), Duration::from_secs(5)).unwrap();
    let req = ScoreRequest { id: None, tokens: vec![1, 2, 3], targets: None };
    let (status, _) = c.request("POST", "/v1/score", Some(&req.to_json())).unwrap();
    assert_eq!(status, 200);
    let gen = GenerateRequest::greedy(None, vec![3, 1, 4], 4);
    let (status, _) = c.request("POST", "/v1/generate", Some(&gen.to_json())).unwrap();
    assert_eq!(status, 200);

    // Same keep-alive connection: the handler sealed each trace before it
    // could read this request, so both are in the ring.
    let doc = c.get_json("/debug/traces?n=8").unwrap();
    assert_eq!(doc.req("enabled").unwrap().as_bool(), Some(true));
    let traces = doc.req("traces").unwrap().as_arr().unwrap();
    assert_eq!(traces.len(), 2, "two requests, two traces");
    let span_names = |t: &Json| -> Vec<String> {
        t.req("spans")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|s| s.req("name").unwrap().as_str().unwrap().to_string())
            .collect()
    };

    // Newest first: the generate session, then the score.
    let (gen_t, score_t) = (&traces[0], &traces[1]);
    assert_eq!(gen_t.req("kind").unwrap().as_str(), Some("generate"));
    assert_eq!(gen_t.req("status").unwrap().as_str(), Some("ok"));
    let gen_spans = span_names(gen_t);
    for want in ["read", "parse", "queue", "claim", "prefill", "reply"] {
        let hit = gen_spans.iter().any(|s| s == want);
        assert!(hit, "generate trace missing {want}: {gen_spans:?}");
    }
    assert_eq!(
        gen_spans.iter().filter(|s| *s == "step").count(),
        3,
        "4 tokens = 1 prefill + 3 decode steps: {gen_spans:?}"
    );
    assert_eq!(score_t.req("kind").unwrap().as_str(), Some("score"));
    assert_eq!(score_t.req("status").unwrap().as_str(), Some("ok"));
    let score_spans = span_names(score_t);
    for want in ["read", "parse", "queue", "claim", "dispatch", "engine_exec", "reply"] {
        let hit = score_spans.iter().any(|s| s == want);
        assert!(hit, "score trace missing {want}: {score_spans:?}");
    }

    // Spans come back sorted by start offset, and totals cover the spans.
    for t in traces {
        let mut prev = 0;
        for s in t.req("spans").unwrap().as_arr().unwrap() {
            let start = s.req("start_us").unwrap().as_usize().unwrap();
            assert!(start >= prev, "span offsets regress");
            prev = start;
        }
        assert!(t.req("total_us").unwrap().as_usize().unwrap() > 0);
    }

    // `?n=1` trims to the newest trace.
    let one = c.get_json("/debug/traces?n=1").unwrap();
    assert_eq!(one.req("traces").unwrap().as_arr().unwrap().len(), 1);

    drop(c);
    server.stop();
}

/// `POST /v1/generate` with sampling knobs, end to end: explicit seeds
/// replay bit-identically regardless of what else shares the decode batch,
/// omitted seeds are picked and echoed by the server, `temperature: 0`
/// short-circuits to greedy, and out-of-range knobs get 400 up front.
#[test]
fn sampled_generation_is_seed_deterministic_e2e() {
    let server = start_server_with(BatchPolicy::Continuous, 5, 128, 16, Duration::from_millis(1));
    let addr = server.addr().to_string();
    let mut c = Client::connect(&addr, Duration::from_secs(5)).unwrap();

    let mut req = GenerateRequest::greedy(Some("s1".into()), vec![2, 7, 1], 8);
    req.temperature = 0.8;
    req.top_k = 6;
    req.top_p = 0.95;
    req.seed = Some(11);
    let (status, body) = c.request("POST", "/v1/generate", Some(&req.to_json())).unwrap();
    assert_eq!(status, 200, "{body}");
    let first = GenerateResponse::parse(&body).unwrap();
    assert_eq!(first.seed, Some(11), "explicit seed echoed back");
    assert_eq!(first.tokens.len(), 8);

    // Replay under a different batch composition: three sampled background
    // sessions share the decode batch while the replay runs. Same seed,
    // same continuation — the batched step is composition-invariant.
    let background: Vec<_> = (0..3)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr, Duration::from_secs(10)).unwrap();
                let mut r = GenerateRequest::greedy(None, vec![9 + i, 4, 2], 20);
                r.temperature = 1.2;
                let (status, body) =
                    c.request("POST", "/v1/generate", Some(&r.to_json())).unwrap();
                assert_eq!(status, 200, "{body}");
                // Omitted seed: the server picks one and must echo it.
                assert!(GenerateResponse::parse(&body).unwrap().seed.is_some());
            })
        })
        .collect();
    let (_, body2) = c.request("POST", "/v1/generate", Some(&req.to_json())).unwrap();
    assert_eq!(
        GenerateResponse::parse(&body2).unwrap().tokens,
        first.tokens,
        "same seed must replay identically under any batch composition"
    );
    for h in background {
        h.join().unwrap();
    }

    // A different seed explores a different continuation.
    req.seed = Some(12);
    let (_, body3) = c.request("POST", "/v1/generate", Some(&req.to_json())).unwrap();
    assert_ne!(GenerateResponse::parse(&body3).unwrap().tokens, first.tokens);

    // Omitted seed: picked, echoed, and replayable.
    req.seed = None;
    let (_, body4) = c.request("POST", "/v1/generate", Some(&req.to_json())).unwrap();
    let picked = GenerateResponse::parse(&body4).unwrap();
    let picked_seed = picked.seed.expect("server must echo the seed it picked");
    req.seed = Some(picked_seed);
    let (_, body5) = c.request("POST", "/v1/generate", Some(&req.to_json())).unwrap();
    assert_eq!(GenerateResponse::parse(&body5).unwrap().tokens, picked.tokens);

    // temperature 0 is greedy no matter the other knobs: identical to a
    // plain greedy request for the same prompt.
    let greedy = GenerateRequest::greedy(None, vec![2, 7, 1], 8);
    let (_, gb) = c.request("POST", "/v1/generate", Some(&greedy.to_json())).unwrap();
    let mut zero = greedy.clone();
    zero.temperature = 0.0;
    zero.top_k = 3;
    zero.top_p = 0.5;
    zero.seed = Some(77);
    let (_, zb) = c.request("POST", "/v1/generate", Some(&zero.to_json())).unwrap();
    assert_eq!(
        GenerateResponse::parse(&zb).unwrap().tokens,
        GenerateResponse::parse(&gb).unwrap().tokens,
        "temperature 0 must be exactly greedy"
    );

    // Out-of-range knobs are rejected before queueing (the docs/API.md
    // validation table).
    for (bad, why) in [
        (GenerateRequest { temperature: -0.5, ..req.clone() }, "negative temperature"),
        (GenerateRequest { top_p: 0.0, ..req.clone() }, "top_p 0"),
        (GenerateRequest { top_p: 1.5, ..req.clone() }, "top_p > 1"),
    ] {
        let (status, body) = c.request("POST", "/v1/generate", Some(&bad.to_json())).unwrap();
        assert_eq!(status, 400, "{why}: {body}");
    }

    drop(c);
    server.stop();
}

/// Streaming wire format over a real socket: chunked transfer-encoding,
/// one newline-terminated JSON event per chunk, indices in order, a
/// terminal `done` event carrying the full response, and a keep-alive
/// connection that serves further requests afterwards.
#[test]
fn streaming_generate_emits_chunked_events_and_keeps_alive() {
    let server = start_server_with(BatchPolicy::Continuous, 5, 128, 16, Duration::from_millis(1));
    let mut c = Client::connect(&server.addr().to_string(), Duration::from_secs(5)).unwrap();

    // Reference: the buffered run of the same prompt.
    let req = GenerateRequest::greedy(Some("st".into()), vec![3, 1, 4], 5);
    let (_, body) = c.request("POST", "/v1/generate", Some(&req.to_json())).unwrap();
    let want = GenerateResponse::parse(&body).unwrap().tokens;

    let mut sreq = req.clone();
    sreq.stream = true;
    let (status, head) =
        c.request_streaming("POST", "/v1/generate", Some(&sreq.to_json())).unwrap();
    assert_eq!(status, 200);
    let h = |name: &str| {
        head.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.to_ascii_lowercase())
    };
    assert_eq!(h("transfer-encoding").as_deref(), Some("chunked"));
    assert_eq!(h("content-type").as_deref(), Some("application/x-ndjson"));
    assert_eq!(h("connection").as_deref(), Some("keep-alive"));

    let mut streamed: Vec<i32> = Vec::new();
    let mut done: Option<String> = None;
    while let Some(chunk) = c.next_chunk().unwrap() {
        assert!(chunk.ends_with('\n'), "one newline-terminated event per chunk: {chunk:?}");
        let ev = Json::parse(chunk.trim()).unwrap();
        match ev.req("event").unwrap().as_str().unwrap() {
            "token" => {
                assert!(done.is_none(), "token event after the terminal event");
                assert_eq!(ev.req("index").unwrap().as_usize(), Some(streamed.len()));
                streamed.push(ev.req("token").unwrap().as_usize().unwrap() as i32);
            }
            "done" => done = Some(chunk.clone()),
            other => panic!("unexpected event {other:?} in {chunk:?}"),
        }
    }
    let done = done.expect("stream must end with a done event");
    let resp = GenerateResponse::parse(&done).unwrap();
    assert_eq!(resp.tokens, want, "streamed continuation == buffered continuation");
    assert_eq!(streamed, want, "token events == the final token list");
    assert_eq!(resp.prompt_len, 3);

    // The connection stays usable for buffered requests after the stream.
    let (status, body2) = c.request("POST", "/v1/generate", Some(&req.to_json())).unwrap();
    assert_eq!(status, 200);
    assert_eq!(GenerateResponse::parse(&body2).unwrap().tokens, want);

    // TTFT / inter-token histograms populated: 3 sessions, 4 gaps each.
    let statz = c.get_json("/statz").unwrap();
    let d = statz.req("decode").unwrap();
    assert_eq!(d.req("ttft").unwrap().req("count").unwrap().as_usize(), Some(3));
    assert_eq!(d.req("inter_token").unwrap().req("count").unwrap().as_usize(), Some(12));

    drop(c);
    server.stop();
}

/// Continuous-mode server whose mock engine decodes slowly against a huge
/// seq_len, so streaming sessions live long enough to observe concurrent
/// interleaving and mid-stream disconnects.
fn start_slow_decode_server(step: Duration) -> Server {
    let slow_seq = 4096;
    let probe = MockEngine::new(MODEL_BATCH, slow_seq);
    let cfg = ServerConfig {
        host: "127.0.0.1".into(),
        port: 0,
        max_connections: 16,
        engines: 1,
        policy: BatchPolicy::Continuous,
        batcher: BatcherConfig {
            max_batch: MODEL_BATCH,
            max_wait: Duration::from_millis(5),
            queue_cap: 128,
        },
        admit_window: Duration::ZERO,
        read_timeout: Duration::from_secs(60),
        request_timeout: Duration::from_secs(30),
        trace: TraceConfig::default(),
        fault: Default::default(),
    };
    let info = EngineInfo {
        seq_len: slow_seq,
        max_batch: MODEL_BATCH,
        vocab: 1024,
        causal: probe.causal,
        decode: true,
        describe: probe.describe(),
        mem: EngineMem::default(),
        gemm_threads: 1,
    };
    let s = Server::start(
        cfg,
        info,
        Arc::new(move || {
            let mut e = MockEngine::new(MODEL_BATCH, slow_seq);
            e.batch_cost = Duration::ZERO;
            e.step_cost = step;
            Ok(Box::new(e) as Box<dyn ScoreEngine>)
        }),
    )
    .unwrap();
    s.wait_ready(Duration::from_secs(10)).unwrap();
    s
}

/// Two concurrent streams make progress together (the batched worker pass
/// steps every live session per iteration), and a client that disconnects
/// mid-stream has its session retired and slot freed long before the
/// session could have run to completion.
#[test]
fn streaming_sessions_interleave_and_disconnect_frees_the_slot() {
    let server = start_slow_decode_server(Duration::from_millis(5));
    let addr = server.addr().to_string();

    // Interleave: both 30-token streams must be in flight at once.
    let run = |prompt: Vec<i32>| {
        let addr = addr.clone();
        std::thread::spawn(move || -> (Instant, Instant) {
            let mut c = Client::connect(&addr, Duration::from_secs(10)).unwrap();
            let mut req = GenerateRequest::greedy(None, prompt, 30);
            req.stream = true;
            let (status, _) =
                c.request_streaming("POST", "/v1/generate", Some(&req.to_json())).unwrap();
            assert_eq!(status, 200);
            let mut times: Vec<Instant> = Vec::new();
            let mut toks: Vec<i32> = Vec::new();
            while let Some(chunk) = c.next_chunk().unwrap() {
                let ev = Json::parse(chunk.trim()).unwrap();
                match ev.req("event").unwrap().as_str().unwrap() {
                    "token" => {
                        times.push(Instant::now());
                        toks.push(ev.req("token").unwrap().as_usize().unwrap() as i32);
                    }
                    "done" => {
                        assert_eq!(GenerateResponse::parse(&chunk).unwrap().tokens, toks);
                    }
                    other => panic!("unexpected event {other:?}"),
                }
            }
            assert_eq!(toks.len(), 30);
            (*times.first().unwrap(), *times.last().unwrap())
        })
    };
    let a = run(vec![1, 2, 3]);
    let b = run(vec![4, 5, 6]);
    let (a0, a1) = a.join().unwrap();
    let (b0, b1) = b.join().unwrap();
    assert!(
        a1 > b0 && b1 > a0,
        "the two streams did not overlap — sessions are serialized, not batched"
    );

    // Mid-stream disconnect: take one token, then drop the socket. The
    // worker's next event send fails and must retire the session.
    {
        let mut c = Client::connect(&addr, Duration::from_secs(10)).unwrap();
        let mut req = GenerateRequest::greedy(None, vec![9, 9, 9], 2000);
        req.stream = true;
        let (status, _) =
            c.request_streaming("POST", "/v1/generate", Some(&req.to_json())).unwrap();
        assert_eq!(status, 200);
        let first = c.next_chunk().unwrap().expect("first streamed event");
        assert!(first.contains("\"token\""), "{first}");
        // Dropping the client closes the TCP stream mid-stream.
    }
    let mut c = Client::connect(&addr, Duration::from_secs(5)).unwrap();
    let mut freed = false;
    for _ in 0..100 {
        let statz = c.get_json("/statz").unwrap();
        let d = statz.req("decode").unwrap();
        if d.req("sessions_active").unwrap().as_usize() == Some(0) {
            assert_eq!(
                statz.req("slots").unwrap().req("generating").unwrap().as_usize(),
                Some(0)
            );
            let toks = d.req("tokens_total").unwrap().as_usize().unwrap();
            // 2 full streams (31 tokens each incl. prefill) + the aborted
            // session, which must die far short of its 2001-token budget.
            assert!((62..500).contains(&toks), "tokens_total {toks}");
            freed = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(freed, "disconnected session never released its slot");

    // The pool still serves fresh sessions afterwards.
    let req = GenerateRequest::greedy(None, vec![8, 8], 3);
    let (status, body) = c.request("POST", "/v1/generate", Some(&req.to_json())).unwrap();
    assert_eq!(status, 200, "{body}");

    drop(c);
    server.stop();
}

/// The tentpole acceptance: under open-loop (Poisson) load at 1.5× the
/// fixed batcher's batch-formation capacity, continuous batching shows a
/// lower p95 queue wait.
///
/// Capacity accounting (MockEngine, deterministic): dispatch cost 5 ms and
/// max_batch 8 give an engine capacity of 1600 req/s; the fixed batcher's
/// flush clock (max_wait 20 ms) can only form batches at max_batch/max_wait
/// = 400 req/s without deadline convoys. We offer 600 req/s — 1.5× the
/// formation capacity, yet only ~0.4× the engine — so the engine always has
/// idle slots, exactly the regime where fixed mode makes requests wait for
/// the flush clock (discrete-event sim: fixed p95 ≈ 15 ms vs continuous
/// ≈ 5 ms, stable across seeds). Past *engine* saturation every
/// work-conserving policy is backlog-bound and the gap closes — that end of
/// the curve is bench_serve's matrix, not an assertion.
#[test]
fn continuous_beats_fixed_p95_queue_wait_under_open_loop() {
    let cost = Duration::from_millis(5);
    let rate = 600.0; // 1.5x formation capacity (8 / 20ms), 0.375x engine
    let run = |policy: BatchPolicy| {
        let server = start_server_with(policy, 20, 1024, 64, cost);
        let report = loadgen::run(&LoadgenConfig {
            addr: server.addr().to_string(),
            clients: 30,
            requests_per_client: 30, // 900 requests ≈ 1.5 s offered load
            vocab: 1024,
            seq_len: SEQ_LEN,
            seed: 11,
            timeout: Duration::from_secs(10),
            open_rate_rps: Some(rate),
            gen: None,
        })
        .unwrap();
        server.stop();
        report
    };

    let attempt = || (run(BatchPolicy::Fixed), run(BatchPolicy::Continuous));
    // Success: no shed/transport errors on either side, the flush-clock
    // convoy actually engaged for fixed, continuous clearly beats it on
    // queue-wait p95, and end-to-end p95 agrees.
    let holds = |f: &loadgen::LoadgenReport, c: &loadgen::LoadgenReport| {
        f.errors == 0
            && c.errors == 0
            && f.ok == 900
            && c.ok == 900
            && f.queue_p95_ms > 6.0
            && c.queue_p95_ms < 0.9 * f.queue_p95_ms
            && c.p95_ms < f.p95_ms
    };
    // The expected margin is ~3x (15 ms vs 5 ms), but queue waits are
    // wall-clock and an oversubscribed CI runner can smear a run (or drop a
    // connection); a single retry absorbs that outlier — a double miss is a
    // real regression.
    let (fixed, cont) = {
        let (f, c) = attempt();
        if holds(&f, &c) {
            (f, c)
        } else {
            attempt()
        }
    };
    assert!(
        holds(&fixed, &cont),
        "continuous did not beat fixed under open-loop load:\n  fixed: ok {} errors {} queue_p95 \
         {:.2} ms p95 {:.2} ms\n  continuous: ok {} errors {} queue_p95 {:.2} ms p95 {:.2} ms",
        fixed.ok,
        fixed.errors,
        fixed.queue_p95_ms,
        fixed.p95_ms,
        cont.ok,
        cont.errors,
        cont.queue_p95_ms,
        cont.p95_ms
    );
}

/// One-slot continuous server for cancellation tests: the single slot is
/// trivially saturated by one in-flight request, so a second request
/// deterministically parks in `WaitingOnSlot`.
fn start_one_slot_server(batch_cost: Duration) -> Server {
    let probe = MockEngine::new(1, SEQ_LEN);
    let cfg = ServerConfig {
        host: "127.0.0.1".into(),
        port: 0,
        max_connections: 16,
        engines: 1,
        policy: BatchPolicy::Continuous,
        batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(5), queue_cap: 8 },
        admit_window: Duration::ZERO,
        read_timeout: Duration::from_secs(60),
        request_timeout: Duration::from_secs(30),
        trace: TraceConfig::default(),
        fault: Default::default(),
    };
    let info = EngineInfo {
        seq_len: SEQ_LEN,
        max_batch: 1,
        vocab: 1024,
        causal: probe.causal,
        decode: true,
        describe: probe.describe(),
        mem: EngineMem::default(),
        gemm_threads: 1,
    };
    let s = Server::start(
        cfg,
        info,
        Arc::new(move || {
            let mut e = MockEngine::new(1, SEQ_LEN);
            e.batch_cost = batch_cost;
            Ok(Box::new(e) as Box<dyn ScoreEngine>)
        }),
    )
    .unwrap();
    s.wait_ready(Duration::from_secs(10)).unwrap();
    s
}

fn statz_num(statz: &Json, dotted: &str) -> f64 {
    let mut cur = statz;
    for part in dotted.split('.') {
        cur = cur.req(part).unwrap_or_else(|e| panic!("{dotted}: {e}"));
    }
    cur.as_f64().unwrap_or_else(|| panic!("{dotted} not a number"))
}

fn wait_for_statz(addr: &str, what: &str, pred: impl Fn(&Json) -> bool) -> Json {
    let t0 = Instant::now();
    loop {
        let mut c = Client::connect(addr, Duration::from_secs(5)).unwrap();
        let statz = c.get_json("/statz").unwrap();
        if pred(&statz) {
            return statz;
        }
        if t0.elapsed() > Duration::from_secs(5) {
            panic!("timed out waiting for {what}; last /statz: {statz}");
        }
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Regression: a client that hangs up while its request is parked in
/// `WaitingOnSlot` must be cancelled — the engine never scores a row
/// nobody will read. Before the fix the abandoned job rode the next
/// batch and burned a full engine call.
#[test]
fn disconnect_while_waiting_on_slot_cancels_without_engine_call() {
    let server = start_one_slot_server(Duration::from_millis(300));
    let addr = server.addr().to_string();

    // A claims the only slot and scores for ~300 ms.
    let a_addr = addr.clone();
    let a = std::thread::spawn(move || {
        let mut c = Client::connect(&a_addr, Duration::from_secs(10)).unwrap();
        let req = ScoreRequest { id: Some("a".into()), tokens: vec![1, 2, 3], targets: None };
        c.request("POST", "/v1/score", Some(&req.to_json())).unwrap()
    });
    wait_for_statz(&addr, "slot claimed", |s| statz_num(s, "slots.free") == 0.0);

    // B parks in WaitingOnSlot behind A, then hangs up.
    let req = ScoreRequest { id: Some("b".into()), tokens: vec![4, 5, 6], targets: None };
    let payload = req.to_json().to_string();
    let mut b = TcpStream::connect(&addr).unwrap();
    write!(
        b,
        "POST /v1/score HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n\r\n{}",
        payload.len(),
        payload
    )
    .unwrap();
    wait_for_statz(&addr, "b waiting on slot", |s| statz_num(s, "connections.waiting") >= 2.0);
    drop(b);
    wait_for_statz(&addr, "cancellation counted", |s| {
        statz_num(s, "requests.cancelled") == 1.0
    });

    let (status, _) = a.join().unwrap();
    assert_eq!(status, 200, "the live request is unaffected by the cancellation");

    // C proves the slot recovered, then the census shows the engine
    // scored exactly A and C — B's row never launched.
    let mut c = Client::connect(&addr, Duration::from_secs(10)).unwrap();
    let req = ScoreRequest { id: Some("c".into()), tokens: vec![7, 8, 9], targets: None };
    let (status, _) = c.request("POST", "/v1/score", Some(&req.to_json())).unwrap();
    assert_eq!(status, 200);
    let statz = c.get_json("/statz").unwrap();
    assert_eq!(statz_num(&statz, "requests.cancelled"), 1.0);
    assert_eq!(statz_num(&statz, "requests.ok"), 2.0);
    assert_eq!(statz_num(&statz, "batches.rows"), 2.0, "cancelled row must not be scored");
    assert_eq!(statz_num(&statz, "slots.free"), 1.0);
    drop(c);
    server.stop();
}

/// `/healthz` distinguishes liveness from readiness: a warming-up server
/// answers 503 `starting` (no error payload) with `ready: false`, and
/// flips to 200 `ok` when the first engine reaches its serving loop.
#[test]
fn healthz_reports_starting_until_engines_ready() {
    let gate = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let probe = MockEngine::new(MODEL_BATCH, SEQ_LEN);
    let cfg = ServerConfig {
        host: "127.0.0.1".into(),
        port: 0,
        max_connections: 16,
        engines: 1,
        policy: BatchPolicy::Continuous,
        batcher: BatcherConfig::default(),
        admit_window: Duration::ZERO,
        read_timeout: Duration::from_secs(60),
        request_timeout: Duration::from_secs(30),
        trace: TraceConfig::default(),
        fault: Default::default(),
    };
    let info = EngineInfo {
        seq_len: SEQ_LEN,
        max_batch: MODEL_BATCH,
        vocab: 1024,
        causal: probe.causal,
        decode: true,
        describe: probe.describe(),
        mem: EngineMem::default(),
        gemm_threads: 1,
    };
    let factory_gate = gate.clone();
    let server = Server::start(
        cfg,
        info,
        Arc::new(move || {
            // Hold engine construction until the test has observed the
            // warming-up healthz (deterministic, no sleep race).
            while !factory_gate.load(std::sync::atomic::Ordering::SeqCst) {
                std::thread::sleep(Duration::from_millis(2));
            }
            Ok(Box::new(MockEngine::new(MODEL_BATCH, SEQ_LEN)) as Box<dyn ScoreEngine>)
        }),
    )
    .unwrap();
    let addr = server.addr().to_string();

    let mut c = Client::connect(&addr, Duration::from_secs(5)).unwrap();
    let (status, body) = c.request("GET", "/healthz", None).unwrap();
    assert_eq!(status, 503);
    let doc = Json::parse(&body).unwrap();
    assert_eq!(doc.req("status").unwrap().as_str(), Some("starting"));
    assert_eq!(doc.req("ready").unwrap().as_bool(), Some(false));
    assert!(doc.get("error").is_none(), "a healthy warm-up carries no error: {body}");

    gate.store(true, std::sync::atomic::Ordering::SeqCst);
    server.wait_ready(Duration::from_secs(10)).unwrap();
    let (status, body) = c.request("GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    let doc = Json::parse(&body).unwrap();
    assert_eq!(doc.req("status").unwrap().as_str(), Some("ok"));
    assert_eq!(doc.req("ready").unwrap().as_bool(), Some(true));
    drop(c);
    server.stop();
}

/// When every engine worker fails at startup, `/healthz` answers 503
/// `unavailable` with the failure reason — distinguishable from the
/// `starting` transient so probes (and humans) stop waiting.
#[test]
fn healthz_reports_unavailable_with_reason_after_startup_failure() {
    let probe = MockEngine::new(MODEL_BATCH, SEQ_LEN);
    let cfg = ServerConfig {
        host: "127.0.0.1".into(),
        port: 0,
        max_connections: 16,
        engines: 1,
        policy: BatchPolicy::Continuous,
        batcher: BatcherConfig::default(),
        admit_window: Duration::ZERO,
        read_timeout: Duration::from_secs(60),
        request_timeout: Duration::from_secs(30),
        trace: TraceConfig::default(),
        fault: Default::default(),
    };
    let info = EngineInfo {
        seq_len: SEQ_LEN,
        max_batch: MODEL_BATCH,
        vocab: 1024,
        causal: probe.causal,
        decode: true,
        describe: probe.describe(),
        mem: EngineMem::default(),
        gemm_threads: 1,
    };
    let server = Server::start(
        cfg,
        info,
        Arc::new(|| anyhow::bail!("engine exploded: checkpoint manifest unreadable")),
    )
    .unwrap();
    let addr = server.addr().to_string();
    assert!(server.wait_ready(Duration::from_secs(5)).is_err(), "startup must fail");

    let t0 = Instant::now();
    let doc = loop {
        let mut c = Client::connect(&addr, Duration::from_secs(5)).unwrap();
        let (status, body) = c.request("GET", "/healthz", None).unwrap();
        assert_eq!(status, 503);
        let doc = Json::parse(&body).unwrap();
        if doc.req("status").unwrap().as_str() == Some("unavailable") {
            break doc;
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "never turned unavailable: {body}");
        std::thread::sleep(Duration::from_millis(10));
    };
    assert_eq!(doc.req("ready").unwrap().as_bool(), Some(false));
    let err = doc.req("error").unwrap().as_str().unwrap();
    assert!(err.contains("engine exploded"), "reason surfaces to probes: {err}");
    assert!(doc.req("startup_failures").unwrap().as_f64().unwrap() >= 1.0);
    server.stop();
}

// ---------------------------------------------------------------------------
// Operable artifacts: /admin/reload + /admin/drain
// ---------------------------------------------------------------------------

/// A continuous-mode server whose mock engines draw from a shared
/// [`WeightHub`], with a reload hook that publishes a new generation and
/// reports a fresh artifact identity — the serving-side shape `qtx serve
/// --mock --artifact-dir` wires up, minus the on-disk package.
fn start_hub_server(batch_cost: Duration, step_cost: Duration) -> (Server, Arc<WeightHub<()>>) {
    let hub = Arc::new(WeightHub::new(Arc::new(())));
    let probe = MockEngine::new(MODEL_BATCH, SEQ_LEN);
    let cfg = ServerConfig {
        host: "127.0.0.1".into(),
        port: 0,
        max_connections: 32,
        engines: 1,
        policy: BatchPolicy::Continuous,
        batcher: BatcherConfig {
            max_batch: MODEL_BATCH,
            max_wait: Duration::from_millis(5),
            queue_cap: 128,
        },
        admit_window: Duration::ZERO,
        read_timeout: Duration::from_secs(60),
        request_timeout: Duration::from_secs(10),
        trace: TraceConfig::default(),
        fault: Default::default(),
    };
    let info = EngineInfo {
        seq_len: SEQ_LEN,
        max_batch: MODEL_BATCH,
        vocab: 1024,
        causal: probe.causal,
        decode: true,
        describe: probe.describe(),
        mem: EngineMem::default(),
        gemm_threads: 1,
    };
    let factory: EngineFactory = {
        let hub = hub.clone();
        Arc::new(move || {
            let mut e = MockEngine::new(MODEL_BATCH, SEQ_LEN).with_hub(hub.clone());
            e.batch_cost = batch_cost;
            e.step_cost = step_cost;
            Ok(Box::new(e) as Box<dyn ScoreEngine>)
        })
    };
    let admin = AdminHooks {
        reload: Some({
            let hub = hub.clone();
            Arc::new(move |dir: &std::path::Path| {
                let generation = hub.publish(Arc::new(()));
                Ok(ReloadOutcome {
                    generation,
                    artifact: Some(ArtifactId {
                        schema: 2,
                        install_id: format!("reload-{}", dir.display()),
                        sha256_short: "feedface0011".into(),
                    }),
                })
            })
        }),
        artifact: Some(ArtifactId {
            schema: 2,
            install_id: "seed-install".into(),
            sha256_short: "aaaabbbbcccc".into(),
        }),
    };
    let s = Server::start_with_admin(cfg, info, factory, admin).unwrap();
    s.wait_ready(Duration::from_secs(10)).unwrap();
    (s, hub)
}

/// Offline greedy replay at a pinned weights generation — the oracle the
/// served transcripts must match bit-exactly.
fn offline_greedy(generation: u64, prompt: &[i32], steps: usize) -> Vec<i32> {
    let mut e = MockEngine::new(MODEL_BATCH, SEQ_LEN).at_generation(generation);
    e.step_cost = Duration::ZERO;
    let mut toks = vec![e.gen_prefill(0, prompt, &SampleParams::greedy()).unwrap()];
    for _ in 1..steps {
        let last = *toks.last().unwrap();
        toks.push(e.gen_step(0, last).unwrap());
    }
    toks
}

/// The hot-reload contract, end to end over TCP: a decode session that
/// was admitted before `POST /admin/reload` finishes bit-exact on its
/// admission-time weights (== a hubless generation-1 offline replay), a
/// session admitted after decodes on the new generation, and `/statz`
/// tracks the generation, reload count, and new artifact identity.
#[test]
fn admin_reload_pins_inflight_sessions_and_switches_new_ones() {
    let (server, _hub) = start_hub_server(Duration::ZERO, Duration::from_millis(2));
    let addr = server.addr().to_string();
    let mut a = Client::connect(&addr, Duration::from_secs(10)).unwrap();
    let mut b = Client::connect(&addr, Duration::from_secs(10)).unwrap();

    // Startup identity: the AdminHooks artifact, generation 1, no reloads.
    let statz = b.get_json("/statz").unwrap();
    let art = statz.req("artifact").unwrap();
    assert_eq!(art.req("install_id").unwrap().as_str(), Some("seed-install"));
    assert_eq!(art.req("sha256_short").unwrap().as_str(), Some("aaaabbbbcccc"));
    assert_eq!(art.req("schema").unwrap().as_usize(), Some(2));
    let w = statz.req("weights").unwrap();
    assert_eq!(w.req("generation").unwrap().as_usize(), Some(1));
    assert_eq!(w.req("reloads").unwrap().as_usize(), Some(0));

    // Admit a streaming session and read its first token, so its prefill
    // provably happened on generation 1...
    let prompt = vec![3, 1, 4];
    let steps = 16usize;
    let mut sreq = GenerateRequest::greedy(None, prompt.clone(), steps);
    sreq.stream = true;
    let (status, _head) =
        a.request_streaming("POST", "/v1/generate", Some(&sreq.to_json())).unwrap();
    assert_eq!(status, 200);
    let first = a.next_chunk().unwrap().expect("first stream event");
    let ev = Json::parse(first.trim()).unwrap();
    assert_eq!(ev.req("event").unwrap().as_str(), Some("token"));
    let mut streamed = vec![ev.req("token").unwrap().as_usize().unwrap() as i32];

    // ...then hot-reload mid-session.
    let body = Json::obj(vec![("dir", Json::Str("/tmp/qtx-next".into()))]);
    let (status, rbody) = b.request("POST", "/admin/reload", Some(&body)).unwrap();
    assert_eq!(status, 200, "{rbody}");
    let rdoc = Json::parse(&rbody).unwrap();
    assert_eq!(rdoc.req("ok").unwrap().as_bool(), Some(true));
    assert_eq!(rdoc.req("generation").unwrap().as_usize(), Some(2));

    // The in-flight session finishes bit-exact on its admission weights.
    while let Some(chunk) = a.next_chunk().unwrap() {
        let ev = Json::parse(chunk.trim()).unwrap();
        match ev.req("event").unwrap().as_str().unwrap() {
            "token" => streamed.push(ev.req("token").unwrap().as_usize().unwrap() as i32),
            "done" => {}
            other => panic!("unexpected event {other:?} in {chunk:?}"),
        }
    }
    let want_old = offline_greedy(1, &prompt, steps);
    assert_eq!(streamed, want_old, "in-flight session must finish on generation-1 weights");

    // A session admitted after the reload decodes on generation 2.
    let req = GenerateRequest::greedy(None, prompt.clone(), steps);
    let (status, body2) = b.request("POST", "/v1/generate", Some(&req.to_json())).unwrap();
    assert_eq!(status, 200, "{body2}");
    let fresh = GenerateResponse::parse(&body2).unwrap().tokens;
    let want_new = offline_greedy(2, &prompt, steps);
    assert_eq!(fresh, want_new, "post-reload sessions must decode on generation-2 weights");
    assert_ne!(fresh, want_old, "the reload must actually change new sessions");

    // `/statz` tracked the swap.
    let statz = b.get_json("/statz").unwrap();
    let w = statz.req("weights").unwrap();
    assert_eq!(w.req("generation").unwrap().as_usize(), Some(2));
    assert_eq!(w.req("reloads").unwrap().as_usize(), Some(1));
    let art = statz.req("artifact").unwrap();
    assert_eq!(art.req("install_id").unwrap().as_str(), Some("reload-/tmp/qtx-next"));
    assert_eq!(art.req("generation").unwrap().as_usize(), Some(2));

    drop(a);
    drop(b);
    server.stop();
}

/// Reload under score load: every request that was in flight or arrives
/// during the swap completes — zero failures — and the server ends on the
/// new generation.
#[test]
fn admin_reload_under_load_drops_no_requests() {
    let (server, _hub) = start_hub_server(Duration::from_millis(2), Duration::from_micros(100));
    let addr = server.addr().to_string();

    let load = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            loadgen::run(&LoadgenConfig {
                addr,
                clients: 4,
                requests_per_client: 60,
                vocab: 128,
                seq_len: 0, // probe /healthz
                seed: 11,
                timeout: Duration::from_secs(10),
                open_rate_rps: None,
                gen: None,
            })
            .unwrap()
        })
    };
    // Land the reload inside the run (the batch cost paces it to >100ms);
    // even a reload that misses the window must drop nothing.
    std::thread::sleep(Duration::from_millis(30));
    let mut c = Client::connect(&addr, Duration::from_secs(5)).unwrap();
    let body = Json::obj(vec![("dir", Json::Str("/tmp/qtx-next".into()))]);
    let (status, rbody) = c.request("POST", "/admin/reload", Some(&body)).unwrap();
    assert_eq!(status, 200, "{rbody}");

    let report = load.join().unwrap();
    assert_eq!(report.errors, 0, "a hot reload must not fail any request");
    assert_eq!(report.ok, 240);

    let statz = c.get_json("/statz").unwrap();
    assert_eq!(statz.req("weights").unwrap().req("generation").unwrap().as_usize(), Some(2));
    drop(c);
    server.stop();
}

/// Drain mode: `POST /admin/drain` refuses new score/generate work with
/// 503 *before* dispatch (deliberate back-pressure — `rejected_full`
/// stays zero), flips `/healthz` to `draining`/`ready: false` so probes
/// route around the replica, and re-enabling restores service on the same
/// connection. Also pins the admin surface's error contract: reload
/// without a hook is 501, a bodyless reload is 400, GET on either admin
/// route is 405.
#[test]
fn admin_drain_stops_admissions_until_reenabled() {
    // Plain server, no admin hooks: drain must work everywhere.
    let server = start_server_with(BatchPolicy::Continuous, 5, 128, 16, Duration::ZERO);
    let addr = server.addr().to_string();
    let mut c = Client::connect(&addr, Duration::from_secs(5)).unwrap();

    let req = ScoreRequest { id: None, tokens: vec![1, 2, 3], targets: None };
    let (status, _) = c.request("POST", "/v1/score", Some(&req.to_json())).unwrap();
    assert_eq!(status, 200);

    // `{}` body: enable is the default.
    let (status, body) = c.request("POST", "/admin/drain", Some(&Json::obj(vec![]))).unwrap();
    assert_eq!(status, 200, "{body}");
    assert_eq!(Json::parse(&body).unwrap().req("draining").unwrap().as_bool(), Some(true));

    // Alive but out of rotation.
    let (status, body) = c.request("GET", "/healthz", None).unwrap();
    assert_eq!(status, 503);
    let doc = Json::parse(&body).unwrap();
    assert_eq!(doc.req("status").unwrap().as_str(), Some("draining"));
    assert_eq!(doc.req("ready").unwrap().as_bool(), Some(false));
    assert_eq!(doc.req("draining").unwrap().as_bool(), Some(true));

    // New work refused before dispatch...
    let (status, body) = c.request("POST", "/v1/score", Some(&req.to_json())).unwrap();
    assert_eq!(status, 503);
    assert!(body.contains("draining"), "{body}");
    let g = GenerateRequest::greedy(None, vec![1, 2], 4);
    let (status, _) = c.request("POST", "/v1/generate", Some(&g.to_json())).unwrap();
    assert_eq!(status, 503);
    // ...and counted as back-pressure, not shed load.
    let statz = c.get_json("/statz").unwrap();
    assert_eq!(
        statz.req("requests").unwrap().req("rejected_full").unwrap().as_usize(),
        Some(0),
        "drain refusals must not count as queue-full sheds"
    );

    // Disable: straight back into rotation on the same keep-alive socket.
    let off = Json::obj(vec![("enable", Json::Bool(false))]);
    let (status, body) = c.request("POST", "/admin/drain", Some(&off)).unwrap();
    assert_eq!(status, 200);
    assert_eq!(Json::parse(&body).unwrap().req("draining").unwrap().as_bool(), Some(false));
    let (status, _) = c.request("GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    let (status, _) = c.request("POST", "/v1/score", Some(&req.to_json())).unwrap();
    assert_eq!(status, 200);

    // Admin error contract.
    let rbody = Json::obj(vec![("dir", Json::Str("/tmp/x".into()))]);
    let (status, _) = c.request("POST", "/admin/reload", Some(&rbody)).unwrap();
    assert_eq!(status, 501, "no reload hook configured");
    let (status, _) = c.request("POST", "/admin/reload", Some(&Json::obj(vec![]))).unwrap();
    assert_eq!(status, 400, "reload needs a dir");
    let (status, _) = c.request("GET", "/admin/reload", None).unwrap();
    assert_eq!(status, 405);
    let (status, _) = c.request("GET", "/admin/drain", None).unwrap();
    assert_eq!(status, 405);

    drop(c);
    server.stop();
}

/// A failing reload hook answers 500 with the hook's error text, keeps
/// the old generation serving, and leaves the connection usable.
#[test]
fn admin_reload_failure_keeps_serving_old_generation() {
    let hub = Arc::new(WeightHub::new(Arc::new(())));
    let probe = MockEngine::new(MODEL_BATCH, SEQ_LEN);
    let cfg = ServerConfig {
        host: "127.0.0.1".into(),
        port: 0,
        max_connections: 16,
        engines: 1,
        policy: BatchPolicy::Continuous,
        batcher: BatcherConfig {
            max_batch: MODEL_BATCH,
            max_wait: Duration::from_millis(5),
            queue_cap: 128,
        },
        admit_window: Duration::ZERO,
        read_timeout: Duration::from_secs(60),
        request_timeout: Duration::from_secs(10),
        trace: TraceConfig::default(),
        fault: Default::default(),
    };
    let info = EngineInfo {
        seq_len: SEQ_LEN,
        max_batch: MODEL_BATCH,
        vocab: 1024,
        causal: probe.causal,
        decode: true,
        describe: probe.describe(),
        mem: EngineMem::default(),
        gemm_threads: 1,
    };
    let factory: EngineFactory = {
        let hub = hub.clone();
        Arc::new(move || {
            Ok(Box::new(MockEngine::new(MODEL_BATCH, SEQ_LEN).with_hub(hub.clone()))
                as Box<dyn ScoreEngine>)
        })
    };
    let admin = AdminHooks {
        reload: Some(Arc::new(|dir: &std::path::Path| {
            anyhow::bail!("entry \"params.bin\" fails its checksum in {dir:?}")
        })),
        artifact: None,
    };
    let server = Server::start_with_admin(cfg, info, factory, admin).unwrap();
    server.wait_ready(Duration::from_secs(10)).unwrap();
    let mut c = Client::connect(&server.addr().to_string(), Duration::from_secs(5)).unwrap();

    let body = Json::obj(vec![("dir", Json::Str("/tmp/corrupt".into()))]);
    let (status, rbody) = c.request("POST", "/admin/reload", Some(&body)).unwrap();
    assert_eq!(status, 500, "{rbody}");
    assert!(rbody.contains("checksum"), "hook error must reach the caller: {rbody}");

    // Nothing swapped; the connection still serves.
    let statz = c.get_json("/statz").unwrap();
    let w = statz.req("weights").unwrap();
    assert_eq!(w.req("generation").unwrap().as_usize(), Some(1));
    assert_eq!(w.req("reloads").unwrap().as_usize(), Some(0));
    let req = ScoreRequest { id: None, tokens: vec![1, 2, 3], targets: None };
    let (status, _) = c.request("POST", "/v1/score", Some(&req.to_json())).unwrap();
    assert_eq!(status, 200);

    drop(c);
    server.stop();
}

/// The startup-failure `/healthz` payload names the artifact dir and its
/// package schema when the engine factory fails the manifest serve gate —
/// the operator reads the fix from the probe, not the server log.
#[test]
fn healthz_startup_failure_names_artifact_dir_and_schema() {
    // A real legacy manifest (no serve_score program, no package block),
    // gated exactly like `qtx serve --engine pjrt` does it.
    const LEGACY: &str = r#"{
      "fingerprint": "x",
      "config": {"name":"c","family":"bert","attention":"softmax",
        "n_layers":2,"d_model":8,"n_heads":2,"seq_len":4,"vocab_size":16,
        "n_classes":0,"patch_dim":0,"batch_size":2,"causal":false,
        "use_gate":false,"objective":"mlm","d_head":4,"ln_placement":"post",
        "patch_ln":false,"gate_hidden":4,"init_std":0.02,"adam_b1":0.9,
        "adam_b2":0.999,"weight_decay":0.01,"grad_clip":1.0,"d_ff":32},
      "params": [],
      "programs": {},
      "quant_points": []
    }"#;
    let probe = MockEngine::new(MODEL_BATCH, SEQ_LEN);
    let cfg = ServerConfig {
        host: "127.0.0.1".into(),
        port: 0,
        max_connections: 16,
        engines: 1,
        policy: BatchPolicy::Continuous,
        batcher: BatcherConfig::default(),
        admit_window: Duration::ZERO,
        read_timeout: Duration::from_secs(60),
        request_timeout: Duration::from_secs(30),
        trace: TraceConfig::default(),
        fault: Default::default(),
    };
    let info = EngineInfo {
        seq_len: SEQ_LEN,
        max_batch: MODEL_BATCH,
        vocab: 1024,
        causal: probe.causal,
        decode: true,
        describe: probe.describe(),
        mem: EngineMem::default(),
        gemm_threads: 1,
    };
    let server = Server::start(
        cfg,
        info,
        Arc::new(|| {
            let m = qtx::runtime::Manifest::parse(LEGACY).expect("fixture parses");
            m.require_serve_score_at(std::path::Path::new("/srv/artifacts/bert_tiny_softmax"))?;
            unreachable!("the legacy manifest has no serve_score program")
        }),
    )
    .unwrap();
    let addr = server.addr().to_string();
    assert!(server.wait_ready(Duration::from_secs(5)).is_err(), "startup must fail");

    let t0 = Instant::now();
    let doc = loop {
        let mut c = Client::connect(&addr, Duration::from_secs(5)).unwrap();
        let (status, body) = c.request("GET", "/healthz", None).unwrap();
        assert_eq!(status, 503);
        let doc = Json::parse(&body).unwrap();
        if doc.req("status").unwrap().as_str() == Some("unavailable") {
            break doc;
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "never turned unavailable: {body}");
        std::thread::sleep(Duration::from_millis(10));
    };
    let err = doc.req("error").unwrap().as_str().unwrap();
    assert!(err.contains("/srv/artifacts/bert_tiny_softmax"), "dir in payload: {err}");
    assert!(err.contains("legacy manifest (no package block)"), "schema label in payload: {err}");
    assert!(err.contains("serve_score"), "root cause in payload: {err}");
    server.stop();
}
