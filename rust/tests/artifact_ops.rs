//! Integration tests for the operable-artifact lifecycle
//! (docs/ARTIFACTS.md): pack → verify → corrupt → doctor → repair →
//! atomic install, plus the crashed-install ("kill -9 mid-install")
//! contract that the tentpole promises: the old destination stays
//! intact and byte-verifiable, and `qtx doctor` flags the leftovers.
//!
//! These drive the same library calls the `qtx pack|install|doctor`
//! subcommands wrap (`runtime::package`), so the CLI exit-code mapping
//! (0 = Ok, 1 = Fixable, 2 = Fail) is pinned here via `DoctorVerdict`.

use std::fs;
use std::path::{Path, PathBuf};

use qtx::runtime::package::{
    self, doctor, install, pack, read_package, stage, verify_dir, DoctorVerdict, PACKAGE_SCHEMA,
};

/// Fresh per-test temp root (removed and recreated so reruns are clean).
fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("qtx-artifact-ops-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

/// A minimal but structurally real artifact dir: a program file, a data
/// file, and a legacy (pre-package) manifest — the state `qtx pack`
/// starts from.
fn fake_artifact(root: &Path, name: &str) -> PathBuf {
    let dir = root.join(name);
    fs::create_dir_all(&dir).unwrap();
    fs::write(dir.join("serve.hlo.txt"), b"HloModule serve\nROOT r = f32[] constant(0)\n").unwrap();
    fs::write(dir.join("weights.bin"), [7u8; 300]).unwrap();
    fs::write(
        dir.join("manifest.json"),
        r#"{"version":5,"fingerprint":"fp_ops","config":{"name":"c","attention":"clipped_softmax","use_gate":true},"quant_points":["embed","L0.q"]}"#,
    )
    .unwrap();
    dir
}

fn notes_joined(report: &package::DoctorReport) -> String {
    report.notes.join("\n")
}

#[test]
fn pack_verify_corrupt_doctor_repair_install_lifecycle() {
    let root = tmpdir("lifecycle");
    let src = fake_artifact(&root, "src");

    // Legacy dir: read_package fails closed and points at the fix.
    let err = format!("{:#}", read_package(&src).unwrap_err());
    assert!(err.contains("legacy manifest"), "unexpected error: {err}");
    assert!(err.contains("qtx pack"), "error should name the fix: {err}");
    let report = doctor(&src);
    assert_eq!(report.verdict, DoctorVerdict::Fixable);
    assert!(notes_joined(&report).contains("legacy manifest"));

    // Pack, then full content verification round-trips.
    let info = pack(&src).unwrap();
    assert_eq!(info.schema, PACKAGE_SCHEMA);
    assert_eq!(info.entries.len(), 2);
    let verified = verify_dir(&src).unwrap();
    assert_eq!(verified.install_id, info.install_id);
    assert_eq!(doctor(&src).verdict, DoctorVerdict::Ok);

    // One flipped byte (same size): verify names the entry and the
    // checksum; doctor reaches the Fail verdict (CLI exit 2).
    let mut corrupt = fs::read(src.join("weights.bin")).unwrap();
    corrupt[150] ^= 0xff;
    fs::write(src.join("weights.bin"), &corrupt).unwrap();
    let err = format!("{:#}", verify_dir(&src).unwrap_err());
    assert!(err.contains("weights.bin"), "error should name the entry: {err}");
    assert!(err.contains("checksum"), "error should say why: {err}");
    let report = doctor(&src);
    assert_eq!(report.verdict, DoctorVerdict::Fail);
    assert!(notes_joined(&report).contains("checksum"));

    // Truncation is a distinct, size-based diagnosis.
    fs::write(src.join("weights.bin"), [7u8; 120]).unwrap();
    let err = format!("{:#}", verify_dir(&src).unwrap_err());
    assert!(err.contains("truncated"), "unexpected error: {err}");
    let report = doctor(&src);
    assert_eq!(report.verdict, DoctorVerdict::Fail);
    assert!(notes_joined(&report).contains("truncated"));

    // A missing entry is also Fail.
    fs::remove_file(src.join("weights.bin")).unwrap();
    assert_eq!(doctor(&src).verdict, DoctorVerdict::Fail);
    assert!(notes_joined(&doctor(&src)).contains("missing"));

    // Repair = restore the payload and repack (re-checksums in place).
    fs::write(src.join("weights.bin"), [7u8; 300]).unwrap();
    let repacked = pack(&src).unwrap();
    assert_eq!(repacked.install_id, info.install_id, "same bytes => same install_id");
    assert_eq!(doctor(&src).verdict, DoctorVerdict::Ok);

    // Atomic install to a fresh destination; the installed copy passes
    // the same full verification and carries the same identity.
    let dest = root.join("installed").join("c");
    let installed = install(&src, &dest).unwrap();
    assert_eq!(installed.install_id, repacked.install_id);
    let at_dest = verify_dir(&dest).unwrap();
    assert_eq!(at_dest.install_id, repacked.install_id);
    assert_eq!(doctor(&dest).verdict, DoctorVerdict::Ok);

    // No staging/lock leftovers after a clean install.
    let parent = dest.parent().unwrap();
    assert!(!parent.join(".staging-c").exists());
    assert!(!parent.join(".c.install.lock").exists());
    assert!(!parent.join(".previous-c").exists());
}

#[test]
fn crashed_install_keeps_old_dest_and_doctor_flags_leftovers() {
    let root = tmpdir("crash");
    let src = fake_artifact(&root, "src");
    let v1 = pack(&src).unwrap();

    // v1 is live at dest.
    let dest = root.join("live").join("c");
    install(&src, &dest).unwrap();
    assert_eq!(verify_dir(&dest).unwrap().install_id, v1.install_id);

    // Build v2 in the source dir (different bytes => different id).
    fs::write(src.join("weights.bin"), [9u8; 300]).unwrap();
    let v2 = pack(&src).unwrap();
    assert_ne!(v2.install_id, v1.install_id);

    // Stage v2 over the live dest, then "crash" before commit: dropping
    // the StagedInstall leaves the staging dir + lockfile on disk.
    let staged = stage(&src, &dest).unwrap();
    let (staging_path, lock_path) = (staged.staging.clone(), staged.lock.clone());
    drop(staged);
    assert!(staging_path.exists(), "crash must leave the staging dir");
    assert!(lock_path.exists(), "crash must leave the lockfile");

    // The destination never changed: still v1, still fully verifiable.
    assert_eq!(verify_dir(&dest).unwrap().install_id, v1.install_id);

    // Doctor on the destination flags both leftovers as Fixable (CLI
    // exit 1), not Fail — the live artifact itself is healthy.
    let report = doctor(&dest);
    assert_eq!(report.verdict, DoctorVerdict::Fixable);
    let notes = notes_joined(&report);
    assert!(notes.contains("staging"), "doctor should flag the staging dir: {notes}");
    assert!(notes.contains("lockfile"), "doctor should flag the lock: {notes}");

    // A second install is blocked by the stale lock, with a message
    // that routes the operator to doctor.
    let err = format!("{:#}", stage(&src, &dest).unwrap_err());
    assert!(err.contains("install lock"), "unexpected error: {err}");
    assert!(err.contains("qtx doctor"), "error should route to doctor: {err}");

    // Operator remediation per the doctor notes: remove the leftovers.
    fs::remove_dir_all(&staging_path).unwrap();
    fs::remove_file(&lock_path).unwrap();
    assert_eq!(doctor(&dest).verdict, DoctorVerdict::Ok);

    // Retry succeeds and swaps dest to v2; the parked v1 copy is gone.
    install(&src, &dest).unwrap();
    assert_eq!(verify_dir(&dest).unwrap().install_id, v2.install_id);
    assert!(!dest.parent().unwrap().join(".previous-c").exists());
    assert_eq!(doctor(&dest).verdict, DoctorVerdict::Ok);
}

#[test]
fn abort_cleans_up_and_unpacked_source_is_refused() {
    let root = tmpdir("abort");
    let src = fake_artifact(&root, "src");

    // install() of a legacy (unpacked) source fails closed before
    // touching the destination.
    let dest = root.join("out").join("c");
    let err = format!("{:#}", install(&src, &dest).unwrap_err());
    assert!(err.contains("legacy manifest"), "unexpected error: {err}");
    assert!(!dest.exists());

    // A deliberate abort removes staging + lock so a retry is clean.
    pack(&src).unwrap();
    let staged = stage(&src, &dest).unwrap();
    let (staging_path, lock_path) = (staged.staging.clone(), staged.lock.clone());
    package::abort(&staged);
    assert!(!staging_path.exists());
    assert!(!lock_path.exists());
    assert!(!dest.exists(), "abort must not create the destination");
    install(&src, &dest).unwrap();
    assert_eq!(doctor(&dest).verdict, DoctorVerdict::Ok);
}
