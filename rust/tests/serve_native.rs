//! Artifact-gated integration tests for the native INT8 backend: the
//! pjrt-vs-native numerical parity acceptance and an end-to-end HTTP serve
//! over the continuous batcher with `--engine native-int8` semantics.
//!
//! Like `rust/tests/integration.rs`, these need `make artifacts` (which
//! also trains the `bert_tiny_softmax` checkpoint) and self-skip loudly
//! otherwise, so plain `cargo test` stays green in a fresh checkout.
//!
//! **Documented tolerance** (see `docs/ARCHITECTURE.md` "Numerical
//! contract"): both engines consume identical quant grids, and the
//! integer GEMMs accumulate exactly in i32, so per-row sums agree up to
//! f32 glue rounding plus at most a few one-step requant flips:
//! `|Δnll| ≤ 0.05 + 0.02·|nll|`, `count` exact, `|Δcorrect| ≤ 2`.

use std::sync::Arc;
use std::time::Duration;

use qtx::infer::NativeInt8Engine;
use qtx::serve::batcher::{BatchPolicy, BatcherConfig};
use qtx::serve::engine::{EngineFactory, EngineSpec, PjrtEngine, ScoreEngine};
use qtx::serve::protocol::{ScoreRequest, ScoreResponse, ScoreRow};
use qtx::serve::server::{Client, EngineInfo, Server, ServerConfig};
use qtx::serve::stats::EngineMem;

fn engine_spec() -> Option<EngineSpec> {
    match EngineSpec::tiny_test_recipe() {
        Ok(spec) => Some(spec),
        Err(why) => {
            eprintln!("SKIPPED: {why}");
            None
        }
    }
}

fn requests(n: usize, seq_len: usize, vocab: usize) -> Vec<ScoreRequest> {
    (0..n)
        .map(|i| {
            let len = 2 + (i * 7) % (seq_len - 1);
            ScoreRequest {
                id: Some(format!("r{i}")),
                tokens: (0..len).map(|j| ((i * 31 + j * 13) % vocab) as i32).collect(),
                targets: None,
            }
        })
        .collect()
}

fn assert_rows_agree(pjrt: &[ScoreRow], native: &[ScoreRow]) {
    assert_eq!(pjrt.len(), native.len());
    for (i, (p, n)) in pjrt.iter().zip(native).enumerate() {
        assert_eq!(p.count, n.count, "row {i}: count");
        let tol = 0.05 + 0.02 * p.nll.abs();
        assert!(
            (p.nll - n.nll).abs() <= tol,
            "row {i}: pjrt nll {} vs native {} exceeds tolerance {tol}",
            p.nll,
            n.nll
        );
        assert!(
            (p.correct - n.correct).abs() <= 2.0,
            "row {i}: pjrt correct {} vs native {}",
            p.correct,
            n.correct
        );
    }
}

/// The tentpole acceptance: the native integer engine reproduces the
/// fake-quant PJRT scores within the documented tolerance — both paths
/// consume the same weight grid and the same calibrated activation grids.
#[test]
fn native_int8_matches_pjrt_scores() {
    let Some(spec) = engine_spec() else { return };
    let mut pjrt = PjrtEngine::new(&spec).unwrap();
    let mut native = NativeInt8Engine::new(&spec).unwrap();
    assert_eq!(pjrt.max_batch(), native.max_batch());
    assert_eq!(pjrt.seq_len(), native.seq_len());
    assert_eq!(pjrt.causal(), native.causal());
    assert!(native.describe().contains("native-int8"));

    // A full batch of varied lengths (padding rows in play), then a
    // partial batch — both dispatch shapes the batcher produces.
    let full = requests(pjrt.max_batch(), pjrt.seq_len(), 256);
    assert_rows_agree(&pjrt.score(&full).unwrap(), &native.score(&full).unwrap());
    let partial = requests(3, pjrt.seq_len(), 256);
    let p = pjrt.score(&partial).unwrap();
    let n = native.score(&partial).unwrap();
    assert_eq!(n.len(), 3, "exactly one row per request");
    assert_rows_agree(&p, &n);

    // Client-supplied targets go through the same packed path.
    let mut with_targets = requests(2, pjrt.seq_len(), 256);
    for r in &mut with_targets {
        let t: Vec<i32> = r.tokens.iter().map(|&t| (t + 1) % 256).collect();
        r.targets = Some(t);
    }
    assert_rows_agree(
        &pjrt.score(&with_targets).unwrap(),
        &native.score(&with_targets).unwrap(),
    );
}

/// `qtx serve --engine native-int8` end-to-end: the native engine behind
/// the slot-based continuous batcher over real TCP, answering `/v1/score`
/// with rows that match the PJRT engine's within tolerance.
#[test]
fn native_int8_serves_http_through_continuous_batcher() {
    let Some(spec) = engine_spec() else { return };

    // Reference rows straight from a PJRT session (no HTTP).
    let mut pjrt = PjrtEngine::new(&spec).unwrap();
    let (max_batch, seq_len, causal) = (pjrt.max_batch(), pjrt.seq_len(), pjrt.causal());
    let reqs = requests(6, seq_len, 256);
    let want = pjrt.score(&reqs).unwrap();
    drop(pjrt);

    let factory: EngineFactory = {
        let spec = spec.clone();
        Arc::new(move || Ok(Box::new(NativeInt8Engine::new(&spec)?) as Box<dyn ScoreEngine>))
    };
    let server = Server::start(
        ServerConfig {
            host: "127.0.0.1".into(),
            port: 0,
            max_connections: 16,
            engines: 1,
            policy: BatchPolicy::Continuous,
            batcher: BatcherConfig {
                max_batch,
                max_wait: Duration::from_millis(5),
                queue_cap: 64,
            },
            admit_window: Duration::ZERO,
            read_timeout: Duration::from_secs(60),
            request_timeout: Duration::from_secs(120),
            trace: qtx::serve::obs::TraceConfig::default(),
            fault: Default::default(),
        },
        EngineInfo {
            seq_len,
            max_batch,
            vocab: 256,
            causal,
            decode: true,
            describe: format!("native-int8:{} W8A8 (test)", spec.config),
            mem: EngineMem::default(),
            gemm_threads: 1,
        },
        factory,
    )
    .unwrap();
    // Native startup calibrates through PJRT once — be generous.
    server.wait_ready(Duration::from_secs(600)).unwrap();
    let addr = server.addr().to_string();

    let mut c = Client::connect(&addr, Duration::from_secs(120)).unwrap();
    let health = c.get_json("/healthz").unwrap();
    assert_eq!(health.req("status").unwrap().as_str(), Some("ok"));
    assert!(
        health.req("engine").unwrap().as_str().unwrap().contains("native-int8"),
        "{health}"
    );

    for (req, want_row) in reqs.iter().zip(&want) {
        let (status, body) = c.request("POST", "/v1/score", Some(&req.to_json())).unwrap();
        assert_eq!(status, 200, "{body}");
        let resp = ScoreResponse::parse(&body).unwrap();
        assert_eq!(resp.id, req.id);
        assert_rows_agree(std::slice::from_ref(want_row), &[resp.row]);
    }

    let statz = c.get_json("/statz").unwrap();
    assert_eq!(statz.req("batch_policy").unwrap().as_str(), Some("continuous"));
    assert_eq!(
        statz.req("batches").unwrap().req("rows").unwrap().as_usize(),
        Some(reqs.len()),
        "every request scored exactly once"
    );

    drop(c);
    server.stop();
}
