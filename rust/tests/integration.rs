//! Integration tests over the real AOT artifacts + PJRT runtime.
//!
//! These need `make artifacts` to have run; they are skipped (with a loud
//! message) when the artifact directory is missing so that plain
//! `cargo test` stays usable in a fresh checkout.

use std::path::PathBuf;

use qtx::coordinator::calibrator::{calibrate, outlier_metrics, CollectOptions};
use qtx::coordinator::evaluator::evaluate;
use qtx::coordinator::quantize::{quantized_eval, QuantSpec};
use qtx::coordinator::trainer::{train, TrainOptions};
use qtx::data::batch::{make_provider, Stream, EVAL_SEED};
use qtx::quant::estimators::EstimatorKind;
use qtx::runtime::artifact::Artifact;
use qtx::runtime::client::Runtime;

fn artifacts_root() -> Option<PathBuf> {
    let root = std::env::var("QTX_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let p = PathBuf::from(root);
    if p.join("bert_tiny_softmax/manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIPPED: no artifacts at {p:?} — run `make artifacts`");
        None
    }
}

#[test]
fn manifests_parse_and_cover_programs() {
    let Some(root) = artifacts_root() else { return };
    for entry in std::fs::read_dir(&root).unwrap() {
        let entry = entry.unwrap();
        if !entry.path().join("manifest.json").exists() {
            continue;
        }
        let name = entry.file_name().to_string_lossy().into_owned();
        let art = Artifact::load(&root, &name).unwrap();
        let m = &art.manifest;
        for prog in ["init", "train_step", "eval_step", "act_collect", "eval_quant"] {
            assert!(m.programs.contains_key(prog), "{name}: missing {prog}");
            assert!(art.dir.join(&m.programs[prog].file).exists());
        }
        assert!(!m.quant_points.is_empty(), "{name}: no quant points");
        // eval_quant's scale vector length must match the quant point list.
        let eq = &m.programs["eval_quant"];
        let scale = eq.inputs.iter().find(|d| d.name == "act_scale").unwrap();
        assert_eq!(scale.shape, vec![m.quant_points.len()], "{name}");
        // train_step state outputs mirror its state inputs, in order.
        let ts = &m.programs["train_step"];
        let n_state = ts.outputs.len() - 1;
        for (i, o) in ts.outputs.iter().take(n_state).enumerate() {
            assert_eq!(o.name, ts.inputs[i].name, "{name}: state order mismatch");
        }
    }
}

/// The end-to-end pipeline on the smallest artifact: init -> train a few
/// steps (loss drops) -> eval -> outlier metrics -> calibrate -> W8A8 eval.
#[test]
fn full_pipeline_bert_tiny() {
    let Some(root) = artifacts_root() else { return };
    let rt = Runtime::cpu().unwrap();
    let art = Artifact::load(&root, "bert_tiny_softmax").unwrap();
    let cfg = art.manifest.config.clone();

    let opts = TrainOptions { log_every: 0, ..TrainOptions::new(7, 25) };
    let mut provider = make_provider(&cfg, 7, Stream::Train);
    let res = train(&rt, &art, &opts, provider.as_mut()).unwrap();
    assert_eq!(res.losses.len(), 25);
    let head = res.losses[..5].iter().sum::<f32>() / 5.0;
    let tail = res.losses[20..].iter().sum::<f32>() / 5.0;
    assert!(tail < head, "loss did not drop: {head} -> {tail}");
    assert_eq!(res.params.len(), art.manifest.params.len());

    let mut eval_p = make_provider(&cfg, EVAL_SEED, Stream::Eval);
    let fp = evaluate(&rt, &art, &res.params, eval_p.as_mut(), 2, 0.0, 1.0, 1.0).unwrap();
    assert!(fp.ppl.is_finite() && fp.ppl > 1.0);
    assert!(fp.ppl < cfg.vocab_size as f64 * 2.0, "ppl {} absurd", fp.ppl);

    let copts = CollectOptions { gamma: 0.0, zeta: 1.0, gate_scale: 1.0 };
    let om = outlier_metrics(&rt, &art, &res.params, eval_p.as_mut(), 2, &copts).unwrap();
    assert!(om.max_inf_norm() > 0.0 && om.avg_kurtosis() > 0.0);

    let mut calib_p = make_provider(&cfg, 1, Stream::Calibration);
    let cal = calibrate(
        &rt, &art, &res.params, calib_p.as_mut(), 2,
        EstimatorKind::Percentile { pct: 99.999 }, &copts, 1,
    )
    .unwrap();
    assert_eq!(cal.n_points(), art.manifest.quant_points.len());
    let qp = cal.finalize(8);
    assert!(qp.iter().all(|q| q.scale > 0.0 && q.scale.is_finite()));

    let q = quantized_eval(
        &rt, &art, &res.params,
        &QuantSpec { calib_batches: 2, ..QuantSpec::w8a8() },
        0.0, 1.0, 1.0, 2, 1,
    )
    .unwrap();
    // At 8 bits on a barely-trained clean model, quantized ppl tracks FP.
    let ratio = q.result.ppl / fp.ppl;
    assert!((0.8..1.3).contains(&ratio), "W8A8/FP ppl ratio {ratio}");
}

/// Determinism: same seed => bit-identical training trajectory.
#[test]
fn training_is_deterministic() {
    let Some(root) = artifacts_root() else { return };
    let rt = Runtime::cpu().unwrap();
    let art = Artifact::load(&root, "opt_tiny_softmax").unwrap();
    let cfg = art.manifest.config.clone();
    let run = |seed| {
        let opts = TrainOptions { log_every: 0, ..TrainOptions::new(seed, 6) };
        let mut p = make_provider(&cfg, seed, Stream::Train);
        train(&rt, &art, &opts, p.as_mut()).unwrap().losses
    };
    assert_eq!(run(3), run(3));
    assert_ne!(run(3), run(4));
}

/// gamma/zeta are runtime inputs: the same artifact must behave differently
/// under different stretch factors (Table 1's one-artifact sweep).
#[test]
fn gamma_is_runtime_input() {
    let Some(root) = artifacts_root() else { return };
    let rt = Runtime::cpu().unwrap();
    let art = Artifact::load(&root, "bert_tiny_softmax").unwrap();
    let cfg = art.manifest.config.clone();
    let opts = TrainOptions { log_every: 0, ..TrainOptions::new(1, 4) };
    let mut p = make_provider(&cfg, 1, Stream::Train);
    let res = train(&rt, &art, &opts, p.as_mut()).unwrap();
    let mut eval_p = make_provider(&cfg, EVAL_SEED, Stream::Eval);
    let a = evaluate(&rt, &art, &res.params, eval_p.as_mut(), 1, 0.0, 1.0, 1.0).unwrap();
    let b = evaluate(&rt, &art, &res.params, eval_p.as_mut(), 1, -0.2, 1.0, 1.0).unwrap();
    assert_ne!(a.ppl, b.ppl);
}

/// Gated-attention artifact: b_init drives the gate openness (Fig 7), and
/// gate_scale=2 (the §B.6 fine-tune trick) changes the forward pass.
#[test]
fn gating_controls_work() {
    let Some(root) = artifacts_root() else { return };
    let rt = Runtime::cpu().unwrap();
    let art = Artifact::load(&root, "bert_tiny_gated_linear").unwrap();
    let cfg = art.manifest.config.clone();
    assert!(cfg.use_gate);
    let run = |b_init: f32, gate_scale: f32| {
        let opts = TrainOptions {
            b_init,
            gate_scale,
            log_every: 0,
            ..TrainOptions::new(1, 3)
        };
        let mut p = make_provider(&cfg, 1, Stream::Train);
        train(&rt, &art, &opts, p.as_mut()).unwrap().losses[0]
    };
    let open = run(4.0, 1.0);
    let closed = run(-4.0, 1.0);
    let scaled = run(4.0, 2.0);
    assert_ne!(open, closed);
    assert_ne!(open, scaled);
}
