//! HTTP-semantics conformance for the event-driven front-end.
//!
//! One shared table of wire-level cases (keep-alive defaults and
//! overrides, 408 stall classification, silent idle close, pipelining,
//! size caps) asserted **twice**: once against the pure
//! [`HttpConn`] state machine (bytes + clock in, events out, no
//! sockets), and once end-to-end over raw `TcpStream`s against a live
//! mock-engine server. The two drivers must agree — that equivalence is
//! what licenses unit-testing protocol edge cases without a socket.
//!
//! Also here: a malformed-input torture corpus fed one byte at a time
//! (no panic, correct 400/408/close, no slot leak), the accept-stage
//! 503 shed, a bounded-thread-count streaming regression, and the
//! `#[ignore]`d 1k-connection smoke CI runs explicitly:
//!
//! ```text
//! cargo test --release --test serve_conformance -- --ignored
//! ```

use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use qtx::serve::batcher::{BatchPolicy, BatcherConfig};
use qtx::serve::conn::{
    ConnEvent, ConnState, HttpConn, ParsedRequest, MAX_BODY_BYTES, MAX_HEAD_BYTES,
};
use qtx::serve::engine::{EngineFactory, MockEngine, ScoreEngine};
use qtx::serve::loadgen::{self, ConnectionHold, LoadgenConfig};
use qtx::serve::obs::TraceConfig;
use qtx::serve::poll::raise_nofile_limit;
use qtx::serve::protocol::{GenerateRequest, ScoreRequest};
use qtx::serve::server::{Client, EngineInfo, Server, ServerConfig};
use qtx::serve::stats::EngineMem;
use qtx::util::json::Json;

const SEQ_LEN: usize = 32;
const MODEL_BATCH: usize = 8;

fn server_config(read_timeout: Duration, max_connections: usize) -> ServerConfig {
    ServerConfig {
        host: "127.0.0.1".into(),
        port: 0, // ephemeral
        max_connections,
        engines: 1,
        policy: BatchPolicy::Continuous,
        batcher: BatcherConfig {
            max_batch: MODEL_BATCH,
            max_wait: Duration::from_millis(5),
            queue_cap: 128,
        },
        admit_window: Duration::ZERO,
        read_timeout,
        request_timeout: Duration::from_secs(10),
        trace: TraceConfig::default(),
        fault: Default::default(),
    }
}

fn engine_info(probe: &MockEngine, seq_len: usize) -> EngineInfo {
    EngineInfo {
        seq_len,
        max_batch: MODEL_BATCH,
        vocab: 1024,
        causal: probe.causal,
        decode: true,
        describe: probe.describe(),
        mem: EngineMem::default(),
        gemm_threads: 1,
    }
}

fn start_mock_server(read_timeout: Duration, max_connections: usize) -> Server {
    let probe = MockEngine::new(MODEL_BATCH, SEQ_LEN);
    let factory: EngineFactory =
        Arc::new(|| Ok(Box::new(MockEngine::new(MODEL_BATCH, SEQ_LEN)) as Box<dyn ScoreEngine>));
    let cfg = server_config(read_timeout, max_connections);
    let s = Server::start(cfg, engine_info(&probe, SEQ_LEN), factory).unwrap();
    s.wait_ready(Duration::from_secs(10)).unwrap();
    s
}

/// One conformance case: wire segments in, an expected connection
/// outcome out. `Stall` means "the client goes quiet here" — the pure
/// driver advances the clock past the read deadline and ticks, the e2e
/// driver simply stops writing and lets the server's deadline fire.
enum Seg {
    Bytes(Vec<u8>),
    Stall,
}

enum Expect {
    /// A well-formed request is answered 200; `keep_alive` is the
    /// RFC 9112 §9.3 persistence decision the server must reach.
    Ok { keep_alive: bool },
    /// A protocol failure: this status + message, then close.
    Err { status: u16, contains: &'static str },
    /// The connection closes without a single response byte.
    Silent,
}

struct Case {
    name: &'static str,
    segments: Vec<Seg>,
    expect: Expect,
    /// For kept-alive outcomes: a follow-up request that must also
    /// succeed on the same connection.
    second_request: Option<Vec<u8>>,
    /// The segments already contain two pipelined requests; expect two
    /// responses without writing anything further.
    pipelined: bool,
}

fn case(name: &'static str, segments: Vec<Seg>, expect: Expect) -> Case {
    Case { name, segments, expect, second_request: None, pipelined: false }
}

/// The shared conformance table. Every entry is run by both
/// `conformance_table_pure_state_machine` and
/// `conformance_table_e2e_raw_sockets`.
fn table() -> Vec<Case> {
    let get11: &[u8] = b"GET /healthz HTTP/1.1\r\n\r\n";
    let get10_ka: &[u8] = b"GET /healthz HTTP/1.0\r\nConnection: keep-alive\r\n\r\n";
    let mut oversized_head = b"GET /healthz HTTP/1.1\r\nX-Big: ".to_vec();
    oversized_head.resize(oversized_head.len() + MAX_HEAD_BYTES, b'x');
    oversized_head.extend_from_slice(b"\r\n");
    let oversized_body = format!(
        "POST /v1/score HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
        MAX_BODY_BYTES + 1
    );
    vec![
        Case {
            second_request: Some(get11.to_vec()),
            ..case(
                "http11_defaults_to_keep_alive",
                vec![Seg::Bytes(get11.to_vec())],
                Expect::Ok { keep_alive: true },
            )
        },
        case(
            "http11_connection_close_honored",
            vec![Seg::Bytes(b"GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n".to_vec())],
            Expect::Ok { keep_alive: false },
        ),
        case(
            "http10_defaults_to_close",
            vec![Seg::Bytes(b"GET /healthz HTTP/1.0\r\n\r\n".to_vec())],
            Expect::Ok { keep_alive: false },
        ),
        Case {
            second_request: Some(get10_ka.to_vec()),
            ..case(
                "http10_keep_alive_opt_in",
                vec![Seg::Bytes(get10_ka.to_vec())],
                Expect::Ok { keep_alive: true },
            )
        },
        Case {
            pipelined: true,
            ..case(
                "pipelined_pair_in_one_buffer",
                vec![Seg::Bytes([get11, get11].concat())],
                Expect::Ok { keep_alive: true },
            )
        },
        case(
            "stall_mid_head_gets_408",
            vec![Seg::Bytes(b"POST /v1/score HT".to_vec()), Seg::Stall],
            Expect::Err { status: 408, contains: "timed out reading request" },
        ),
        case(
            "stall_mid_body_gets_408",
            vec![
                Seg::Bytes(b"POST /v1/score HTTP/1.1\r\nContent-Length: 64\r\n\r\n{\"tok".to_vec()),
                Seg::Stall,
            ],
            Expect::Err { status: 408, contains: "timed out reading request" },
        ),
        case("idle_zero_bytes_closes_silently", vec![Seg::Stall], Expect::Silent),
        case(
            "oversized_head_rejected_400",
            vec![Seg::Bytes(oversized_head)],
            Expect::Err { status: 400, contains: "header section exceeds" },
        ),
        case(
            "oversized_body_rejected_400",
            vec![Seg::Bytes(oversized_body.into_bytes())],
            Expect::Err { status: 400, contains: "exceeds" },
        ),
        case(
            "bad_content_length_rejected_400",
            vec![Seg::Bytes(b"POST /v1/score HTTP/1.1\r\nContent-Length: nope\r\n\r\n".to_vec())],
            Expect::Err { status: 400, contains: "bad content-length" },
        ),
    ]
}

fn expect_request(ev: Option<ConnEvent>, ctx: &str) -> ParsedRequest {
    match ev {
        Some(ConnEvent::Request(r)) => r,
        other => panic!("[{ctx}] expected a parsed request, got {other:?}"),
    }
}

/// Every table case against the pure machine: feed the segments,
/// check the emitted event and the keep-alive / close decision.
#[test]
fn conformance_table_pure_state_machine() {
    for case in table() {
        let timeout = Duration::from_millis(100);
        let t0 = Instant::now();
        let mut c = HttpConn::new(t0, timeout);
        let mut now = t0;
        let mut ev: Option<ConnEvent> = None;
        for seg in &case.segments {
            let e = match seg {
                Seg::Bytes(b) => c.on_bytes(b, now),
                Seg::Stall => {
                    now += timeout + Duration::from_millis(1);
                    c.on_tick(now)
                }
            };
            if e.is_some() {
                assert!(ev.is_none(), "[{}] machine emitted two events", case.name);
                ev = e;
            }
        }
        match &case.expect {
            Expect::Ok { keep_alive } => {
                let req = expect_request(ev, case.name);
                assert_eq!(req.keep_alive, *keep_alive, "[{}] keep-alive decision", case.name);
                assert_eq!(c.state(), ConnState::WaitingOnSlot, "[{}]", case.name);
                let next = c.response_complete(*keep_alive, now);
                if case.pipelined {
                    let req2 = expect_request(next, case.name);
                    assert_eq!(req2.path(), "/healthz", "[{}] pipelined request", case.name);
                    assert!(c.response_complete(true, now).is_none(), "[{}]", case.name);
                    assert_eq!(c.state(), ConnState::Idle, "[{}]", case.name);
                } else if !keep_alive {
                    assert!(next.is_none(), "[{}]", case.name);
                    assert_eq!(c.state(), ConnState::Closed, "[{}] must close", case.name);
                } else {
                    assert!(next.is_none(), "[{}]", case.name);
                    assert_eq!(c.state(), ConnState::Idle, "[{}] must stay open", case.name);
                    if let Some(second) = &case.second_request {
                        let req2 = expect_request(c.on_bytes(second, now), case.name);
                        assert!(req2.keep_alive, "[{}] second request", case.name);
                    }
                }
            }
            Expect::Err { status, contains } => match ev {
                Some(ConnEvent::Error { status: s, message, .. }) => {
                    assert_eq!(s, *status, "[{}]", case.name);
                    assert!(message.contains(contains), "[{}] message {message:?}", case.name);
                    assert_eq!(c.state(), ConnState::Closed, "[{}]", case.name);
                }
                other => panic!("[{}] expected a {status}, got {other:?}", case.name),
            },
            Expect::Silent => match ev {
                Some(ConnEvent::CloseSilent) => {
                    assert_eq!(c.state(), ConnState::Closed, "[{}]", case.name);
                }
                other => panic!("[{}] expected a silent close, got {other:?}", case.name),
            },
        }
    }
}

/// Read exactly one HTTP response (head + Content-Length body) off a
/// raw socket, returning it as text.
fn read_one_response(s: &mut TcpStream, ctx: &str) -> String {
    let mut buf = Vec::new();
    let mut byte = [0u8; 1];
    while !buf.ends_with(b"\r\n\r\n") {
        match s.read(&mut byte) {
            Ok(1) => buf.push(byte[0]),
            other => panic!("[{ctx}] connection ended mid-head: {other:?} after {buf:?}"),
        }
    }
    let head = String::from_utf8_lossy(&buf).to_string();
    let len: usize = head
        .to_ascii_lowercase()
        .lines()
        .find_map(|l| l.strip_prefix("content-length:").map(|v| v.trim().parse().unwrap()))
        .unwrap_or(0);
    let mut body = vec![0u8; len];
    s.read_exact(&mut body).unwrap();
    head + &String::from_utf8_lossy(&body)
}

/// The same table over raw sockets against a live server. A short
/// server-side read timeout (300 ms) makes the stall/idle cases fast;
/// well-formed requests complete orders of magnitude sooner.
#[test]
fn conformance_table_e2e_raw_sockets() {
    let server = start_mock_server(Duration::from_millis(300), 16);
    let addr = server.addr();
    for case in table() {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        for seg in &case.segments {
            if let Seg::Bytes(b) = seg {
                // The server may legally respond-and-close before the
                // last bytes land (oversized head), so a write error
                // here is not a failure — the response assert decides.
                let _ = s.write_all(b);
            }
        }
        match &case.expect {
            Expect::Ok { keep_alive: false } => {
                let mut buf = String::new();
                s.read_to_string(&mut buf).unwrap(); // EOF only if the server closed
                assert!(buf.starts_with("HTTP/1.1 200"), "[{}] {buf:?}", case.name);
                let lower = buf.to_ascii_lowercase();
                assert!(lower.contains("connection: close"), "[{}] {buf:?}", case.name);
            }
            Expect::Ok { keep_alive: true } => {
                let first = read_one_response(&mut s, case.name);
                assert!(first.starts_with("HTTP/1.1 200"), "[{}] {first:?}", case.name);
                let lower = first.to_ascii_lowercase();
                assert!(lower.contains("connection: keep-alive"), "[{}] {first:?}", case.name);
                if case.pipelined {
                    let second = read_one_response(&mut s, case.name);
                    assert!(second.starts_with("HTTP/1.1 200"), "[{}] {second:?}", case.name);
                }
                if let Some(req2) = &case.second_request {
                    s.write_all(req2).unwrap();
                    let second = read_one_response(&mut s, case.name);
                    assert!(second.starts_with("HTTP/1.1 200"), "[{}] {second:?}", case.name);
                }
            }
            Expect::Err { status, contains } => {
                let mut buf = String::new();
                s.read_to_string(&mut buf).unwrap();
                let want = format!("HTTP/1.1 {status}");
                assert!(buf.starts_with(&want), "[{}] {buf:?}", case.name);
                assert!(buf.contains(contains), "[{}] {buf:?}", case.name);
                let lower = buf.to_ascii_lowercase();
                assert!(lower.contains("connection: close"), "[{}] {buf:?}", case.name);
            }
            Expect::Silent => {
                let mut buf = Vec::new();
                s.read_to_end(&mut buf).unwrap();
                assert!(buf.is_empty(), "[{}] idle close wrote bytes: {buf:?}", case.name);
            }
        }
    }
    server.stop();
}

/// The accept-stage shed: with every connection slot taken, one more
/// connect gets a deterministic 503 written on the fresh socket and
/// closed — no engine slot claimed, no established connection harmed.
#[test]
fn shed_503_at_accept_without_consuming_a_slot() {
    let server = start_mock_server(Duration::from_secs(60), 2);
    let addr = server.addr();

    // Fill both connection slots with established keep-alive sockets.
    let req: &[u8] = b"GET /healthz HTTP/1.1\r\n\r\n";
    let mut a = TcpStream::connect(addr).unwrap();
    a.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    a.write_all(req).unwrap();
    assert!(read_one_response(&mut a, "conn a").starts_with("HTTP/1.1 200"));
    let mut b = TcpStream::connect(addr).unwrap();
    b.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    b.write_all(req).unwrap();
    assert!(read_one_response(&mut b, "conn b").starts_with("HTTP/1.1 200"));

    // Connection cap + 1: shed with a 503 before any request bytes.
    let mut shed = TcpStream::connect(addr).unwrap();
    shed.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut buf = String::new();
    shed.read_to_string(&mut buf).unwrap();
    assert!(buf.starts_with("HTTP/1.1 503"), "shed connect must 503: {buf:?}");
    assert!(buf.contains("connection limit reached"), "{buf:?}");
    assert!(buf.to_ascii_lowercase().contains("connection: close"), "{buf:?}");

    // The established connections are untouched by the shed.
    a.write_all(req).unwrap();
    assert!(read_one_response(&mut a, "conn a after shed").starts_with("HTTP/1.1 200"));
    b.write_all(req).unwrap();
    assert!(read_one_response(&mut b, "conn b after shed").starts_with("HTTP/1.1 200"));

    // Free one socket, then verify through /statz that the shed never
    // touched the engine: every slot free, and exactly the two live
    // sockets (conn a + this statz client) on the connection census.
    drop(b);
    let addr_s = addr.to_string();
    let mut verified = false;
    for _ in 0..100 {
        let mut c = Client::connect(&addr_s, Duration::from_secs(5)).unwrap();
        if let Ok(statz) = c.get_json("/statz") {
            if let Ok(conns) = statz.req("connections") {
                let open = conns.req("open").unwrap().as_usize().unwrap();
                let free = statz.req("slots").unwrap().req("free").unwrap().as_usize().unwrap();
                if open == 2 && free == MODEL_BATCH {
                    verified = true;
                    break;
                }
            }
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(verified, "statz never showed 2 open connections + all slots free after the shed");

    drop(a);
    server.stop();
}

/// A reference request split at every byte boundary parses identically:
/// exactly one event, emitted on the final byte, same parse every time.
#[test]
fn torture_reference_request_split_at_every_boundary() {
    let wire: &[u8] = b"POST /v1/score HTTP/1.1\r\nContent-Type: application/json\r\n\
                        Content-Length: 18\r\nConnection: close\r\n\r\n{\"tokens\":[1,2,3]}";
    for split in 0..=wire.len() {
        let now = Instant::now();
        let mut c = HttpConn::new(now, Duration::from_secs(1));
        let first = c.on_bytes(&wire[..split], now);
        if split < wire.len() {
            assert!(first.is_none(), "event before the last byte (split {split})");
        }
        let ev = first.or_else(|| c.on_bytes(&wire[split..], now));
        let req = expect_request(ev, &format!("split {split}"));
        assert_eq!(req.method, "POST");
        assert_eq!(req.path(), "/v1/score");
        assert_eq!(req.header("content-type"), Some("application/json"));
        assert_eq!(req.body, b"{\"tokens\":[1,2,3]}");
        assert!(!req.keep_alive);
    }
}

/// Malformed-input corpus, fed one byte at a time: no panic, the
/// documented 400/close classification, events only at the final byte
/// (or at EOF for the early-close cases).
#[test]
fn torture_corpus_byte_at_a_time() {
    struct Torture {
        name: &'static str,
        wire: Vec<u8>,
        /// Feed EOF after the bytes (peer closed early).
        eof: bool,
        /// `None` = a valid request must come out; `Some` = this 400.
        expect_err: Option<&'static str>,
    }
    let corpus = vec![
        Torture {
            name: "lf_only_line_endings_parse",
            wire: b"POST /v1/score HTTP/1.1\nContent-Length: 2\n\nok".to_vec(),
            eof: false,
            expect_err: None,
        },
        Torture {
            name: "garbage_start_line_still_yields_a_request",
            wire: b"\x00\xfe\xffzap\r\n\r\n".to_vec(),
            eof: false,
            expect_err: None,
        },
        Torture {
            name: "bad_content_length",
            wire: b"POST /v1/score HTTP/1.1\r\nContent-Length: twelve\r\n\r\n".to_vec(),
            eof: false,
            expect_err: Some("bad content-length"),
        },
        Torture {
            name: "negative_content_length",
            wire: b"POST /v1/score HTTP/1.1\r\nContent-Length: -5\r\n\r\n".to_vec(),
            eof: false,
            expect_err: Some("bad content-length"),
        },
        Torture {
            name: "early_close_mid_body",
            wire: b"POST /v1/score HTTP/1.1\r\nContent-Length: 64\r\n\r\n{\"tok".to_vec(),
            eof: true,
            expect_err: Some("reading body: failed to fill whole buffer"),
        },
        Torture {
            name: "early_close_mid_headers",
            wire: b"GET /healthz HTTP/1.1\r\nX-Partial: yes\r\n".to_vec(),
            eof: true,
            expect_err: Some("eof in headers"),
        },
    ];
    for t in corpus {
        let now = Instant::now();
        let mut c = HttpConn::new(now, Duration::from_secs(1));
        let mut ev: Option<ConnEvent> = None;
        for (i, b) in t.wire.iter().enumerate() {
            let e = c.on_bytes(std::slice::from_ref(b), now);
            if e.is_some() {
                assert!(!t.eof, "[{}] event before EOF", t.name);
                assert_eq!(i, t.wire.len() - 1, "[{}] event before the last byte", t.name);
                ev = e;
            }
        }
        if t.eof {
            ev = c.on_eof(now);
        }
        match t.expect_err {
            None => {
                expect_request(ev, t.name);
            }
            Some(contains) => match ev {
                Some(ConnEvent::Error { status: 400, message, .. }) => {
                    assert!(message.contains(contains), "[{}] message {message:?}", t.name);
                }
                other => panic!("[{}] expected a 400, got {other:?}", t.name),
            },
        }
        assert!(c.on_bytes(b"trailing", now).is_none(), "[{}] machine must be closed", t.name);
    }
}

/// The torture cases end-to-end: byte-at-a-time writes, early closes,
/// and malformed heads against a live server — correct statuses, and
/// afterwards the slot pool is fully free (nothing leaked) and still
/// serving.
#[test]
fn torture_e2e_malformed_inputs_leak_no_slots() {
    let server = start_mock_server(Duration::from_secs(60), 16);
    let addr = server.addr();

    // Reference request fed one byte at a time still scores.
    let wire: &[u8] = b"POST /v1/score HTTP/1.1\r\nContent-Length: 18\r\n\
                        Connection: close\r\n\r\n{\"tokens\":[1,2,3]}";
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    for b in wire.iter() {
        s.write_all(std::slice::from_ref(b)).unwrap();
    }
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    assert!(buf.starts_with("HTTP/1.1 200"), "byte-at-a-time score: {buf:?}");

    // LF-only line endings are accepted.
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.write_all(b"GET /healthz HTTP/1.1\nConnection: close\n\n").unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    assert!(buf.starts_with("HTTP/1.1 200"), "LF-only request: {buf:?}");

    // Unparseable content-length: 400 and close.
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.write_all(b"POST /v1/score HTTP/1.1\r\nContent-Length: nope\r\n\r\n").unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    assert!(buf.starts_with("HTTP/1.1 400"), "{buf:?}");
    assert!(buf.contains("bad content-length"), "{buf:?}");

    // Early close mid-body: the blocking parser's exact classification.
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.write_all(b"POST /v1/score HTTP/1.1\r\nContent-Length: 64\r\n\r\n{\"tok").unwrap();
    s.shutdown(Shutdown::Write).unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    assert!(buf.starts_with("HTTP/1.1 400"), "{buf:?}");
    assert!(buf.contains("failed to fill whole buffer"), "{buf:?}");

    // Nothing leaked: the pool drains to fully free and still serves.
    let addr_s = addr.to_string();
    let mut c = Client::connect(&addr_s, Duration::from_secs(5)).unwrap();
    let req = ScoreRequest { id: None, tokens: vec![5, 6, 7], targets: None };
    let (status, body) = c.request("POST", "/v1/score", Some(&req.to_json())).unwrap();
    assert_eq!(status, 200, "{body}");
    let mut free = 0;
    for _ in 0..50 {
        let statz = c.get_json("/statz").unwrap();
        free = statz.req("slots").unwrap().req("free").unwrap().as_usize().unwrap();
        if free == MODEL_BATCH {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(free, MODEL_BATCH, "malformed traffic leaked an engine slot");

    drop(c);
    server.stop();
}

/// Concurrent token streams all progress while the server runs exactly
/// one I/O thread — the event loop multiplexes them; no thread per
/// stream, no parked writer threads. The `connections.streaming` gauge
/// must see them, and the streams must demonstrably overlap in time.
#[test]
fn concurrent_streams_progress_on_one_io_thread() {
    let slow_seq = 4096;
    let probe = MockEngine::new(MODEL_BATCH, slow_seq);
    let mut cfg = server_config(Duration::from_secs(60), 16);
    cfg.request_timeout = Duration::from_secs(30);
    let factory: EngineFactory = Arc::new(move || {
        let mut e = MockEngine::new(MODEL_BATCH, slow_seq);
        e.step_cost = Duration::from_millis(10);
        Ok(Box::new(e) as Box<dyn ScoreEngine>)
    });
    let server = Server::start(cfg, engine_info(&probe, slow_seq), factory).unwrap();
    server.wait_ready(Duration::from_secs(10)).unwrap();
    let addr = server.addr().to_string();

    let handles: Vec<_> = (0..4)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || -> (Instant, Instant) {
                let mut c = Client::connect(&addr, Duration::from_secs(10)).unwrap();
                let mut req = GenerateRequest::greedy(None, vec![i + 1, 2, 3], 25);
                req.stream = true;
                let (status, _) =
                    c.request_streaming("POST", "/v1/generate", Some(&req.to_json())).unwrap();
                assert_eq!(status, 200);
                let mut first = None;
                let mut last = None;
                let mut tokens = 0;
                while let Some(chunk) = c.next_chunk().unwrap() {
                    let ev = Json::parse(chunk.trim()).unwrap();
                    match ev.req("event").unwrap().as_str().unwrap() {
                        "token" => {
                            let t = Instant::now();
                            first.get_or_insert(t);
                            last = Some(t);
                            tokens += 1;
                        }
                        "done" => {}
                        other => panic!("unexpected event {other:?} in {chunk:?}"),
                    }
                }
                assert_eq!(tokens, 25, "stream {i} must decode to max_new_tokens");
                (first.unwrap(), last.unwrap())
            })
        })
        .collect();

    // While the streams run: one I/O thread, and the connection census
    // sees them streaming.
    let mut c = Client::connect(&addr, Duration::from_secs(5)).unwrap();
    let mut max_streaming = 0;
    let mut polls = 0;
    while handles.iter().any(|h| !h.is_finished()) && polls < 2000 {
        let statz = c.get_json("/statz").unwrap();
        let server_info = statz.req("server").unwrap();
        assert_eq!(server_info.req("io_threads").unwrap().as_usize(), Some(1));
        let streaming =
            statz.req("connections").unwrap().req("streaming").unwrap().as_usize().unwrap();
        max_streaming = max_streaming.max(streaming);
        polls += 1;
        std::thread::sleep(Duration::from_millis(5));
    }
    let spans: Vec<(Instant, Instant)> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let latest_first = spans.iter().map(|s| s.0).max().unwrap();
    let earliest_last = spans.iter().map(|s| s.1).min().unwrap();
    assert!(latest_first < earliest_last, "the four streams never overlapped");
    assert!(max_streaming >= 2, "streaming gauge never saw concurrency ({max_streaming})");

    drop(c);
    server.stop();
}

/// The 1k-connection smoke (CI runs it explicitly with `-- --ignored`):
/// 1000 mostly-idle keep-alive connections held open, a trickle of
/// scores proving they stay serviceable, and a fixed score load whose
/// p95 must stay flat vs. a 16-connection baseline — all on one I/O
/// thread. Needs ~2.5k file descriptors (CI sets `ulimit -n 8192`).
#[test]
#[ignore]
fn smoke_1k_connections_p95_flat_on_one_io_thread() {
    let limit = raise_nofile_limit(8192);
    assert!(
        limit >= 2500,
        "the 1k smoke needs ~2.5k fds, got a limit of {limit}; raise `ulimit -n`"
    );
    let server = start_mock_server(Duration::from_secs(60), 1200);
    let addr = server.addr().to_string();

    let measure = |held: usize| -> f64 {
        let mut hold = ConnectionHold::open(&addr, held, Duration::from_secs(10)).unwrap();
        assert_eq!(hold.len(), held);
        // A trickle of scores across rotating held sockets: the idle
        // mass stays serviceable, not just open.
        let score = ScoreRequest { id: None, tokens: vec![1, 2, 3, 4], targets: None }.to_json();
        for i in 0..held.min(32) {
            let status = hold.trickle(i * 97, "POST", "/v1/score", Some(&score)).unwrap();
            assert_eq!(status, 200, "trickle over held connection {i} with {held} held");
        }
        let mut c = Client::connect(&addr, Duration::from_secs(5)).unwrap();
        let statz = c.get_json("/statz").unwrap();
        let server_info = statz.req("server").unwrap();
        assert_eq!(server_info.req("io_threads").unwrap().as_usize(), Some(1));
        let open = statz.req("connections").unwrap().req("open").unwrap().as_usize().unwrap();
        assert!(open >= held, "expected >= {held} open connections, /statz says {open}");
        let report = loadgen::run(&LoadgenConfig {
            addr: addr.clone(),
            clients: 2,
            requests_per_client: 100,
            vocab: 128,
            seq_len: 0, // probe /healthz
            seed: 5,
            timeout: Duration::from_secs(10),
            open_rate_rps: None,
            gen: None,
        })
        .unwrap();
        assert_eq!(report.errors, 0, "loadgen errors with {held} held connections");
        assert_eq!(report.ok, 200);
        drop(c);
        drop(hold);
        report.p95_ms
    };

    let p95_16 = measure(16);
    let p95_1k = measure(1000);
    // "Flat" with a generous CI margin: held-idle connections cost a
    // poll-set scan, not a thread each, so p95 must not blow up.
    assert!(
        p95_1k <= 5.0 * p95_16.max(0.5) + 25.0,
        "p95 blew up under 1k idle connections: 16-conn p95 {p95_16:.2} ms vs \
         1k-conn p95 {p95_1k:.2} ms"
    );

    server.stop();
}
