#!/usr/bin/env bash
# Tier-1 verification gate (referenced from ROADMAP.md).
#
#   scripts/verify.sh            build + test + fmt + clippy
#   scripts/verify.sh --fast     build + test only
#
# Requires the vendored rust toolchain; artifact-dependent integration
# tests self-skip when `make artifacts` has not been run.

set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "verify.sh: cargo not found on PATH — this container lacks the rust" >&2
    echo "toolchain; run on an image with the vendored rust_pallas toolchain." >&2
    exit 1
fi

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

if [[ "${1:-}" == "--fast" ]]; then
    echo "verify.sh: OK (fast)"
    exit 0
fi

echo "== cargo fmt --check"
cargo fmt -- --check

echo "== cargo clippy -D warnings"
cargo clippy --all-targets -- -D warnings

echo "verify.sh: OK"
