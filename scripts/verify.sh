#!/usr/bin/env bash
# Tier-1 verification gate (referenced from ROADMAP.md; `make verify`).
#
#   scripts/verify.sh            build + test + fmt + clippy + rustdoc + links
#   scripts/verify.sh --fast     build + test only
#   scripts/verify.sh --ci       full gate + GitHub step summary
#                                (markdown appended to $GITHUB_STEP_SUMMARY)
#
# Requires the vendored rust toolchain; artifact-dependent integration
# tests self-skip when `make artifacts` has not been run.

set -euo pipefail
cd "$(dirname "$0")/.."

FAST=0
CI=0
for arg in "$@"; do
    case "$arg" in
        --fast) FAST=1 ;;
        --ci) CI=1 ;;
        *) echo "verify.sh: unknown flag $arg (want --fast or --ci)" >&2; exit 2 ;;
    esac
done

SUMMARY="${GITHUB_STEP_SUMMARY:-/dev/null}"
summarize() { if [[ "$CI" == 1 ]]; then echo "$*" >>"$SUMMARY"; fi; }

if ! command -v cargo >/dev/null 2>&1; then
    echo "verify.sh: cargo not found on PATH — this container lacks the rust" >&2
    echo "toolchain; run on an image with the vendored rust_pallas toolchain." >&2
    exit 1
fi

summarize "## tier-1 verify"
summarize ""

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
TEST_LOG="$(mktemp)"
if cargo test -q 2>&1 | tee "$TEST_LOG"; then
    summarize '```'
    # The per-target result lines are the signal CI readers want.
    if [[ "$CI" == 1 ]]; then
        grep -E '^test result:' "$TEST_LOG" >>"$SUMMARY" || true
    fi
    summarize '```'
else
    summarize "**cargo test FAILED**"
    summarize '```'
    if [[ "$CI" == 1 ]]; then
        tail -n 40 "$TEST_LOG" >>"$SUMMARY" || true
    fi
    summarize '```'
    rm -f "$TEST_LOG"
    exit 1
fi
rm -f "$TEST_LOG"

if [[ "$FAST" == 1 ]]; then
    echo "verify.sh: OK (fast)"
    summarize "fast mode: lint passes skipped"
    exit 0
fi

echo "== cargo fmt --check"
cargo fmt -- --check

echo "== cargo clippy -D warnings"
cargo clippy --all-targets -- -D warnings

# Broken intra-doc links and malformed rustdoc fail the gate: the docs
# surface (crate-level //! docs, docs/*.md) is part of tier 1.
echo "== cargo doc --no-deps (RUSTDOCFLAGS=-D warnings)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== markdown link check"
scripts/check_links.sh

summarize "build, tests, fmt, clippy, rustdoc and doc links all green."
# (the bench trajectory summary is ci.yml's own step — `make bench` runs
# after verify, so the file does not exist yet here)

echo "verify.sh: OK"
