#!/usr/bin/env bash
# Observability smoke for CI: start a mock `qtx serve`, push a little
# traffic through it, scrape `GET /metricz`, and keep the exposition as
# METRICZ_snapshot.txt (uploaded as a CI artifact next to BENCH_*.json).
# Fails if the exposition is missing the expected families/samples.
#
#   scripts/scrape_metricz.sh [OUT.txt]         (default: METRICZ_snapshot.txt)
#   scripts/scrape_metricz.sh OUT.txt PORT      attach mode: scrape an
#       already-running server (a `qtx route` fleet, say) at PORT instead
#       of starting a mock — no traffic is sent and only generic
#       exposition sanity is checked (the caller owns the surface).
#
# Pure bash + /dev/tcp — the CI toolchain image carries no curl.

set -euo pipefail
cd "$(dirname "$0")/.."

OUT="${1:-METRICZ_snapshot.txt}"
ATTACH="${2:-}"
PORT="${ATTACH:-${QTX_SCRAPE_PORT:-8791}}"
BIN=target/release/qtx
[[ -x "$BIN" ]] || cargo build --release

SERVER=""
if [[ -z "$ATTACH" ]]; then
    "$BIN" serve --mock --port "$PORT" &
    SERVER=$!
    trap 'kill "$SERVER" 2>/dev/null || true; wait "$SERVER" 2>/dev/null || true' EXIT
fi

# One-shot HTTP over /dev/tcp; HTTP/1.0 so the server closes for us.
# Prints the response body (headers stripped at the blank line).
http_get() {
    exec 3<>"/dev/tcp/127.0.0.1/$PORT"
    printf 'GET %s HTTP/1.0\r\nHost: localhost\r\n\r\n' "$1" >&3
    sed $'1,/^\r*$/d' <&3
    exec 3<&- 3>&-
}

http_post() {
    local body=$2
    exec 3<>"/dev/tcp/127.0.0.1/$PORT"
    printf 'POST %s HTTP/1.0\r\nHost: localhost\r\nContent-Length: %d\r\n\r\n%s' \
        "$1" "${#body}" "$body" >&3
    sed $'1,/^\r*$/d' <&3
    exec 3<&- 3>&-
}

# The server binds before its engine workers report ready — poll /healthz.
ready=0
for _ in $(seq 1 100); do
    if body=$(http_get /healthz 2>/dev/null) && [[ "$body" == *'"ok"'* ]]; then
        ready=1
        break
    fi
    sleep 0.1
done
[[ "$ready" == 1 ]] || { echo "scrape_metricz: server never became healthy" >&2; exit 1; }

if [[ -z "$ATTACH" ]]; then
    # Traffic so counters, histograms, and decode telemetry are non-trivial.
    http_post /v1/score '{"tokens": [1, 2, 3]}' >/dev/null
    http_post /v1/generate '{"tokens": [3, 1, 4], "max_new_tokens": 4}' >/dev/null
fi

http_get /metricz >"$OUT"

if [[ -z "$ATTACH" ]]; then
    # Sanity: families announced, counters carry the traffic we sent.
    grep -q '^# TYPE qtx_requests_total counter$' "$OUT"
    grep -q '^# TYPE qtx_latency_seconds histogram$' "$OUT"
    grep -q '^# TYPE qtx_quant_gate_off_ratio gauge$' "$OUT"
    grep -q '^qtx_requests_ok 2$' "$OUT"
else
    # Attach mode: the surface varies (serve vs route) — require a
    # well-formed, non-empty exposition.
    grep -q '^# TYPE qtx_' "$OUT"
fi
echo "scrape_metricz: wrote $OUT ($(wc -l <"$OUT") lines)"
