#!/usr/bin/env bash
# Routing smoke for CI: a three-replica mock fleet behind `qtx route`,
# one replica rigged to kill its front-end mid-run (`--fault
# kill-after:20`), and a closed-loop `qtx loadgen` drill through the
# router. The acceptance bar is the ROUTING.md contract: every score
# request lands a 200 or a deliberate 503 shed — any other failure cause
# (reset, refused, timeout, 5xx) means a request was lost and the step
# fails. Afterwards the router's /metricz is scraped (attach mode of
# scrape_metricz.sh) and archived as ROUTE_METRICZ_snapshot.txt.
#
#   scripts/route_smoke.sh
#
# Ports: QTX_ROUTE_SMOKE_PORT (router, default 8793) and the next three
# for replicas. Pure bash + /dev/tcp — no curl in the toolchain image.

set -euo pipefail
cd "$(dirname "$0")/.."

ROUTER_PORT="${QTX_ROUTE_SMOKE_PORT:-8793}"
R1=$((ROUTER_PORT + 1)); R2=$((ROUTER_PORT + 2)); R3=$((ROUTER_PORT + 3))
BIN=target/release/qtx
[[ -x "$BIN" ]] || cargo build --release

PIDS=()
cleanup() {
    for pid in "${PIDS[@]}"; do kill "$pid" 2>/dev/null || true; done
    for pid in "${PIDS[@]}"; do wait "$pid" 2>/dev/null || true; done
}
trap cleanup EXIT

"$BIN" serve --mock --port "$R1" & PIDS+=($!)
# The doomed replica: its 20th dispatched request (well inside the 120
# the drill sends) closes the listener and drops every connection.
"$BIN" serve --mock --port "$R2" --fault kill-after:20 & PIDS+=($!)
"$BIN" serve --mock --port "$R3" & PIDS+=($!)
# Test-speed probe cadence (the production defaults would make CI wait
# out multi-second eject cycles for nothing).
"$BIN" route --port "$ROUTER_PORT" \
    --backends "127.0.0.1:$R1,127.0.0.1:$R2,127.0.0.1:$R3" \
    --probe-interval-ms 25 --probe-timeout-ms 250 --eject-after 2 \
    --halfopen-ms 50 --retry-backoff-ms 5 & PIDS+=($!)

http_get() { # http_get PORT PATH
    exec 3<>"/dev/tcp/127.0.0.1/$1"
    printf 'GET %s HTTP/1.0\r\nHost: localhost\r\n\r\n' "$2" >&3
    sed $'1,/^\r*$/d' <&3
    exec 3<&- 3>&-
}

ready=0
for _ in $(seq 1 100); do
    if body=$(http_get "$ROUTER_PORT" /healthz 2>/dev/null) \
        && [[ "$body" == *'"ok"'* ]]; then
        ready=1
        break
    fi
    sleep 0.1
done
[[ "$ready" == 1 ]] || { echo "route_smoke: router never became ready" >&2; exit 1; }

# 120 closed-loop scores across the kill; loadgen counts failures per
# cause instead of aborting, so the report is the verdict.
LOADGEN_OUT=$("$BIN" loadgen --port "$ROUTER_PORT" --threads 4 --requests 30)
echo "$LOADGEN_OUT"
json=$(grep '^loadgen JSON:' <<<"$LOADGEN_OUT" | sed 's/^loadgen JSON: //')
[[ -n "$json" ]] || { echo "route_smoke: no loadgen JSON line" >&2; exit 1; }

# Fail on any non-shed failure cause. 503 sheds are the admission
# contract under saturation; everything else is a lost request.
causes=$(sed -n 's/.*"errors_by_cause":{\([^}]*\)}.*/\1/p' <<<"$json")
non_shed=$(tr ',' '\n' <<<"$causes" | grep -v '^[[:space:]]*$' \
    | grep -v '"http_503"' || true)
if [[ -n "$non_shed" ]]; then
    echo "route_smoke: non-shed failures through the router: $non_shed" >&2
    exit 1
fi

# The kill must actually have happened and been noticed: poll the fleet
# census until the doomed replica shows up ejected.
ejected=0
for _ in $(seq 1 100); do
    if statz=$(http_get "$ROUTER_PORT" /statz 2>/dev/null) \
        && [[ "$statz" == *'"ejected":1'* ]]; then
        ejected=1
        break
    fi
    sleep 0.1
done
[[ "$ejected" == 1 ]] || {
    echo "route_smoke: doomed replica was never ejected" >&2
    http_get "$ROUTER_PORT" /statz >&2 || true
    exit 1
}

# Archive the router's exposition next to the serve-side snapshot.
scripts/scrape_metricz.sh ROUTE_METRICZ_snapshot.txt "$ROUTER_PORT"
grep -q '^# TYPE qtx_route_replicas_ejected gauge$' ROUTE_METRICZ_snapshot.txt
grep -q '^# TYPE qtx_route_requests_retries counter$' ROUTE_METRICZ_snapshot.txt
echo "route_smoke: 120 requests over a mid-run replica kill, no lost requests"
