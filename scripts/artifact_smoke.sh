#!/usr/bin/env bash
# Artifact-lifecycle smoke for CI: synthesize an artifact dir, walk it
# through `qtx pack` → corruption → `qtx doctor` (exit 2) → repair →
# `qtx install` → `qtx serve --mock --artifact-dir`, then hot-swap the
# weight generation with `POST /admin/reload` while `qtx loadgen` runs
# and drill `POST /admin/drain`. The acceptance bar is the
# docs/ARTIFACTS.md contract: doctor's exit codes match its verdicts,
# install never disturbs a live dir, and the reload loses zero requests.
# Every doctor run is appended to ARTIFACT_DOCTOR_transcript.txt, which
# CI archives next to the bench trajectories.
#
#   scripts/artifact_smoke.sh
#
# Port: QTX_ARTIFACT_SMOKE_PORT (default 8797). Pure bash + /dev/tcp —
# no curl in the toolchain image.

set -euo pipefail
cd "$(dirname "$0")/.."

PORT="${QTX_ARTIFACT_SMOKE_PORT:-8797}"
BIN=target/release/qtx
[[ -x "$BIN" ]] || cargo build --release

WORK=target/artifact_smoke
rm -rf "$WORK"
mkdir -p "$WORK"
SRC="$WORK/src/bert_tiny_softmax"
DEST="$WORK/installed/bert_tiny_softmax"
TRANSCRIPT=ARTIFACT_DOCTOR_transcript.txt
: > "$TRANSCRIPT"

PIDS=()
cleanup() {
    for pid in "${PIDS[@]}"; do kill "$pid" 2>/dev/null || true; done
    for pid in "${PIDS[@]}"; do wait "$pid" 2>/dev/null || true; done
}
trap cleanup EXIT

# doctor_expect RC LABEL DIR: run `qtx doctor`, append the transcript,
# fail unless the exit code matches the expected verdict.
doctor_expect() {
    local want="$1" label="$2" dir="$3" rc=0 out
    out=$("$BIN" doctor --dir "$dir" 2>&1) || rc=$?
    {
        echo "== qtx doctor --dir $dir ($label; exit $rc, expected $want) =="
        echo "$out"
        echo
    } >> "$TRANSCRIPT"
    if [[ "$rc" != "$want" ]]; then
        echo "artifact_smoke: doctor($label) exited $rc, expected $want:" >&2
        echo "$out" >&2
        exit 1
    fi
}

# A minimal but structurally real artifact dir (same shape the package
# unit tests use): one program file, one 300-byte payload, a legacy
# manifest for `qtx pack` to upgrade in place.
make_payload() {
    mkdir -p "$SRC"
    printf 'HloModule serve\nROOT r = f32[] constant(0)\n' > "$SRC/serve.hlo.txt"
    head -c 300 /dev/zero | tr '\0' 'A' > "$SRC/weights.bin"
}
make_payload
cat > "$SRC/manifest.json" <<'EOF'
{"version":5,"fingerprint":"fp_smoke","config":{"name":"bert_tiny_softmax","attention":"clipped_softmax","use_gate":false},"quant_points":["embed","L0.q"]}
EOF

# Legacy dir: fixable (exit 1) — doctor names `qtx pack` as the fix.
doctor_expect 1 "legacy manifest" "$SRC"

"$BIN" pack --dir "$SRC"
doctor_expect 0 "freshly packed" "$SRC"

# One flipped byte, same size: checksum failure, exit 2.
printf 'B' | dd of="$SRC/weights.bin" bs=1 seek=10 count=1 conv=notrunc status=none
doctor_expect 2 "corrupted payload" "$SRC"

# Repair = restore the payload and repack.
make_payload
"$BIN" pack --dir "$SRC"
doctor_expect 0 "repaired" "$SRC"

"$BIN" install --from "$SRC" --to "$DEST"
doctor_expect 0 "installed" "$DEST"

# Serve the installed dir's identity on the mock engine; /admin/reload
# re-verifies it and publishes the next weight generation.
"$BIN" serve --mock --port "$PORT" --artifact-dir "$DEST" & PIDS+=($!)

http_get() { # http_get PATH -> body
    exec 3<>"/dev/tcp/127.0.0.1/$PORT"
    printf 'GET %s HTTP/1.0\r\nHost: localhost\r\n\r\n' "$1" >&3
    sed $'1,/^\r*$/d' <&3
    exec 3<&- 3>&-
}

http_post() { # http_post PATH BODY -> status line + body on separate lines
    local body="$2"
    exec 3<>"/dev/tcp/127.0.0.1/$PORT"
    printf 'POST %s HTTP/1.0\r\nHost: localhost\r\nContent-Type: application/json\r\nContent-Length: %s\r\n\r\n%s' \
        "$1" "${#body}" "$body" >&3
    awk 'NR==1{print; next} blank{print} /^\r?$/{blank=1}' <&3
    exec 3<&- 3>&-
}

ready=0
for _ in $(seq 1 100); do
    if body=$(http_get /healthz 2>/dev/null) && [[ "$body" == *'"ok"'* ]]; then
        ready=1
        break
    fi
    sleep 0.1
done
[[ "$ready" == 1 ]] || { echo "artifact_smoke: server never became ready" >&2; exit 1; }

# Startup identity: /statz carries the installed package's schema.
statz=$(http_get /statz)
[[ "$statz" == *'"artifact"'* && "$statz" == *'"schema":2'* ]] || {
    echo "artifact_smoke: /statz missing the artifact identity block:" >&2
    echo "$statz" >&2
    exit 1
}

# Hot reload under load: 120 closed-loop scores with a mid-run swap.
"$BIN" loadgen --port "$PORT" --threads 4 --requests 30 > "$WORK/loadgen.out" & LG=$!
PIDS+=($LG)
sleep 0.3
reload=$(http_post /admin/reload "{\"dir\": \"$DEST\"}")
[[ "$reload" == *" 200 "* && "$reload" == *'"generation":2'* ]] || {
    echo "artifact_smoke: /admin/reload failed: $reload" >&2
    exit 1
}
wait "$LG"
cat "$WORK/loadgen.out"
json=$(grep '^loadgen JSON:' "$WORK/loadgen.out" | sed 's/^loadgen JSON: //')
[[ -n "$json" ]] || { echo "artifact_smoke: no loadgen JSON line" >&2; exit 1; }
causes=$(sed -n 's/.*"errors_by_cause":{\([^}]*\)}.*/\1/p' <<<"$json")
if [[ -n "$(tr -d '[:space:]' <<<"$causes")" ]]; then
    echo "artifact_smoke: requests lost across the reload: $causes" >&2
    exit 1
fi

statz=$(http_get /statz)
[[ "$statz" == *'"reloads":1'* ]] || {
    echo "artifact_smoke: /statz does not show the reload:" >&2
    echo "$statz" >&2
    exit 1
}

# Drain drill: admissions stop (healthz degrades to draining), then
# re-enabling restores service.
drain=$(http_post /admin/drain '{}')
[[ "$drain" == *'"draining":true'* ]] || {
    echo "artifact_smoke: /admin/drain did not engage: $drain" >&2
    exit 1
}
health=$(http_get /healthz)
[[ "$health" == *'"draining"'* ]] || {
    echo "artifact_smoke: draining server still reports healthy: $health" >&2
    exit 1
}
undrain=$(http_post /admin/drain '{"enable": false}')
[[ "$undrain" == *'"draining":false'* ]] || {
    echo "artifact_smoke: /admin/drain did not release: $undrain" >&2
    exit 1
}
health=$(http_get /healthz)
[[ "$health" == *'"ok"'* ]] || {
    echo "artifact_smoke: server did not recover from drain: $health" >&2
    exit 1
}

echo "artifact_smoke: pack/doctor/install lifecycle + 120 requests over a hot reload, no lost requests"
