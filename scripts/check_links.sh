#!/usr/bin/env bash
# Markdown link check (offline): every relative link/image target in the
# checked files must exist on disk. http(s)/mailto links and pure anchors
# are skipped — CI has no network and anchor slugs are renderer-specific.
#
#   scripts/check_links.sh [FILE.md ...]   (default: README, docs/, ROADMAP)
#
# Pure bash + grep so it runs in both CI jobs (rust image and python job).

set -euo pipefail
cd "$(dirname "$0")/.."

files=("$@")
if [[ ${#files[@]} -eq 0 ]]; then
    files=(README.md ROADMAP.md docs/*.md)
fi

fail=0
for f in "${files[@]}"; do
    if [[ ! -f "$f" ]]; then
        echo "check_links: missing file $f" >&2
        fail=1
        continue
    fi
    dir=$(dirname "$f")
    # Extract (target) of every [text](target) / ![alt](target).
    while IFS= read -r target; do
        case "$target" in
            http://*|https://*|mailto:*|\#*) continue ;;
        esac
        path="${target%%#*}"            # drop fragment
        [[ -z "$path" ]] && continue
        if [[ ! -e "$dir/$path" ]]; then
            echo "check_links: $f -> broken link ($target)" >&2
            fail=1
        fi
    done < <(grep -oE '\]\([^)[:space:]]+\)' "$f" | sed -E 's/^\]\(//; s/\)$//')
done

if [[ "$fail" != 0 ]]; then
    echo "check_links: FAILED" >&2
    exit 1
fi
echo "check_links: OK (${#files[@]} files)"
