//! Integer GEMM kernel micro-bench: scalar reference vs the detected SIMD
//! tier, per kernel (`u8×i8`, `u8×u8`) and per model GEMM shape.
//!
//! Shapes are the repo's actual serving GEMMs: q/k/v + output projections
//! and both FFN matmuls at the tiny (`d=64`, batch 32×64) and mid
//! (`d=128`, batch 16×64) configs, plus the per-head attention products
//! (`t×t×dh` scores, `t×dh×t` context). Each (kernel, shape) cell runs on
//! `Tier::Scalar` and on the detected tier; before timing, both outputs
//! are compared `==` as a belt-and-braces check on the property-tested
//! bit-exactness contract.
//!
//! Output: a markdown table (the repo's bench idiom) plus one
//! `bench_gemm JSON: {...}` line per (kernel, shape, tier) — `make bench`
//! collects these into `BENCH_gemm.json`, which CI archives next to
//! `BENCH_serve.json`. The headline number is `speedup_vs_scalar` of the
//! SIMD rows: the acceptance target is ≥ 2× for `u8×i8` at the model
//! shapes on an AVX2 host.
//!
//! Run: cargo bench --bench bench_gemm
//! Env: QTX_BENCH_GEMM_TARGET_OPS  int8 ops per timed cell (default 3e8)
//!      QTX_SIMD=scalar            force both rows scalar (sanity)

use std::time::Instant;

use qtx::infer::gemm::{gemm_q8_tier, gemm_q8q8_tier, Int8Weight, QView};
use qtx::infer::simd::Tier;
use qtx::metrics::table::render;
use qtx::util::json::Json;
use qtx::util::rng::Rng;

#[derive(Clone, Copy)]
enum Kernel {
    U8I8,
    U8U8,
}

impl Kernel {
    fn name(self) -> &'static str {
        match self {
            Kernel::U8I8 => "u8i8",
            Kernel::U8U8 => "u8u8",
        }
    }
}

struct Shape {
    label: &'static str,
    kernel: Kernel,
    m: usize,
    k: usize,
    n: usize,
}

/// The GEMM shapes the native engine actually dispatches (see
/// `rust/src/infer/model.rs`): m = batch·seq rows for projections/FFN,
/// per-head t×t / t×dh for attention.
fn shapes() -> Vec<Shape> {
    let s = |label, kernel, m, k, n| Shape { label, kernel, m, k, n };
    vec![
        // bert_tiny / opt_tiny: d=64, ff=256, batch 32×64.
        s("proj_tiny d64", Kernel::U8I8, 2048, 64, 64),
        s("ffn1_tiny d64->256", Kernel::U8I8, 2048, 64, 256),
        s("ffn2_tiny 256->d64", Kernel::U8I8, 2048, 256, 64),
        // opt_mid/opt_big-ish: d=128, ff=512, batch 16×64.
        s("proj_mid d128", Kernel::U8I8, 1024, 128, 128),
        s("ffn1_mid d128->512", Kernel::U8I8, 1024, 128, 512),
        // attention per head: scores t×t over dh, context t×dh over t.
        s("scores_head t64 dh16", Kernel::U8U8, 64, 16, 64),
        s("ctx_head t64 dh16", Kernel::U8U8, 64, 64, 16),
        s("scores_head t64 dh32", Kernel::U8U8, 64, 32, 64),
    ]
}

fn rand_u8(rng: &mut Rng, n: usize) -> Vec<u8> {
    (0..n).map(|_| rng.below(256) as u8).collect()
}

struct Cell {
    tier: Tier,
    ms_per_call: f64,
    gintops: f64,
    gib_per_s: f64,
}

/// Time one (kernel, shape) on `tier`; `out` is the preallocated result.
fn run_cell(sh: &Shape, tier: Tier, target_ops: f64, rng: &mut Rng) -> (Cell, Vec<f32>) {
    let (m, k, n) = (sh.m, sh.k, sh.n);
    let a_data = rand_u8(rng, m * k);
    let a = QView { data: &a_data, scale: 0.017, zero_point: 101 };
    let mut out = vec![0.0f32; m * n];
    let ops_per_call = 2.0 * m as f64 * n as f64 * k as f64;
    let iters = ((target_ops / ops_per_call) as usize).clamp(3, 100_000);
    let el = match sh.kernel {
        Kernel::U8I8 => {
            let wt: Vec<i8> = (0..n * k).map(|_| (rng.below(256) as i32 - 128) as i8).collect();
            let col_sum = wt.chunks_exact(k).map(|c| c.iter().map(|&v| v as i32).sum()).collect();
            let w = Int8Weight { k, n, wt, scale: 0.004, col_sum };
            let bias: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
            gemm_q8_tier(tier, a, m, &w, Some(&bias), &mut out); // warm-up
            let t0 = Instant::now();
            for _ in 0..iters {
                gemm_q8_tier(tier, a, m, &w, Some(&bias), &mut out);
            }
            t0.elapsed().as_secs_f64()
        }
        Kernel::U8U8 => {
            let b_data = rand_u8(rng, n * k);
            let bt = QView { data: &b_data, scale: 0.008, zero_point: 77 };
            let mut sums = vec![0i32; m + n];
            gemm_q8q8_tier(tier, a, bt, m, n, k, &mut sums, &mut out); // warm-up
            let t0 = Instant::now();
            for _ in 0..iters {
                gemm_q8q8_tier(tier, a, bt, m, n, k, &mut sums, &mut out);
            }
            t0.elapsed().as_secs_f64()
        }
    };
    let bytes_per_call = (m * k + n * k + 4 * m * n) as f64;
    (
        Cell {
            tier,
            ms_per_call: el / iters as f64 * 1e3,
            gintops: ops_per_call * iters as f64 / el / 1e9,
            gib_per_s: bytes_per_call * iters as f64 / el / (1u64 << 30) as f64,
        },
        out,
    )
}

fn main() {
    let target_ops: f64 = std::env::var("QTX_BENCH_GEMM_TARGET_OPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3e8);
    let simd = qtx::infer::simd::active_tier();
    eprintln!("[bench_gemm] detected tier: {}", simd.name());

    let mut table: Vec<Vec<String>> = Vec::new();
    for sh in shapes() {
        // Fresh deterministic data per shape; identical for both tiers.
        let (scalar, scalar_out) = run_cell(&sh, Tier::Scalar, target_ops, &mut Rng::new(99));
        let (fast, fast_out) = run_cell(&sh, simd, target_ops, &mut Rng::new(99));
        assert_eq!(
            scalar_out, fast_out,
            "{} {}: SIMD output diverged from the scalar reference",
            sh.kernel.name(),
            sh.label
        );
        let speedup = scalar.ms_per_call / fast.ms_per_call;
        for cell in [&scalar, &fast] {
            println!(
                "bench_gemm JSON: {}",
                Json::obj(vec![
                    ("kernel", Json::Str(sh.kernel.name().into())),
                    ("shape", Json::Str(sh.label.into())),
                    ("m", Json::Num(sh.m as f64)),
                    ("k", Json::Num(sh.k as f64)),
                    ("n", Json::Num(sh.n as f64)),
                    ("tier", Json::Str(cell.tier.name().into())),
                    ("ms_per_call", Json::Num(cell.ms_per_call)),
                    ("gintops", Json::Num(cell.gintops)),
                    ("gib_per_s", Json::Num(cell.gib_per_s)),
                    (
                        "speedup_vs_scalar",
                        Json::Num(if std::ptr::eq(cell, &fast) { speedup } else { 1.0 }),
                    ),
                ])
            );
        }
        eprintln!(
            "[bench_gemm] {} {:<22} scalar {:>8.3} ms  {} {:>8.3} ms  ({:.2}x)",
            sh.kernel.name(),
            sh.label,
            scalar.ms_per_call,
            fast.tier.name(),
            fast.ms_per_call,
            speedup
        );
        table.push(vec![
            sh.kernel.name().to_string(),
            sh.label.to_string(),
            format!("{}x{}x{}", sh.m, sh.k, sh.n),
            format!("{:.3}", scalar.ms_per_call),
            format!("{:.1}", scalar.gintops),
            format!("{:.3}", fast.ms_per_call),
            format!("{:.1}", fast.gintops),
            format!("{:.1}", fast.gib_per_s),
            format!("{:.2}x", speedup),
        ]);
    }

    println!(
        "\n## integer GEMM kernels — scalar vs {} (bit-exact outputs asserted)\n\n{}",
        simd.name(),
        render(
            &[
                "kernel", "shape", "m x k x n", "scalar ms", "scalar Gop/s", "simd ms",
                "simd Gop/s", "simd GiB/s", "speedup"
            ],
            &table
        )
    );
    if simd == Tier::Scalar {
        println!(
            "\nnote: no SIMD tier detected on this host (or QTX_SIMD=scalar) — both rows \
             ran the scalar reference."
        );
    }
}
