//! L3 coordinator profile: where a training step's wall-clock goes.
//!
//! Decomposes the hot loop into (a) batch synthesis, (b) host->literal
//! conversion, (c) XLA execution + readback, and separately times the PTQ
//! pipeline stages (act_collect, range estimation, eval_quant). This is
//! the measurement behind EXPERIMENTS.md §Perf / DESIGN.md §8.
//!
//! Run: cargo bench --bench bench_pipeline

use std::time::Instant;

use qtx::coordinator::calibrator::{calibrate, CollectOptions};
use qtx::coordinator::evaluator::evaluate;
use qtx::coordinator::quantize::{quantized_eval, QuantSpec};
use qtx::coordinator::trainer::{train, TrainOptions};
use qtx::data::batch::{make_provider, Stream};
use qtx::quant::estimators::EstimatorKind;
use qtx::runtime::artifact::Artifact;
use qtx::runtime::client::Runtime;

fn main() -> anyhow::Result<()> {
    let (root, _) = qtx::coordinator::experiment::default_paths();
    let rt = Runtime::cpu()?;
    let art = Artifact::load(&root, "bert_tiny_softmax")?;
    let cfg = art.manifest.config.clone();
    let n = std::env::var("QTX_BENCH_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(16usize);

    // (a) batch synthesis alone
    let mut provider = make_provider(&cfg, 0, Stream::Train);
    let t0 = Instant::now();
    for _ in 0..n {
        std::hint::black_box(provider.next_batch());
    }
    let batch_ms = t0.elapsed().as_secs_f64() * 1000.0 / n as f64;

    // (b) literal conversion alone
    let batch = provider.next_batch();
    let t0 = Instant::now();
    for _ in 0..n {
        for (_, v) in &batch.values {
            std::hint::black_box(v.to_literal()?);
        }
    }
    let lit_ms = t0.elapsed().as_secs_f64() * 1000.0 / n as f64;

    // (c) full training step (execution dominates)
    let warm = TrainOptions { log_every: 0, ..TrainOptions::new(0, 3) };
    train(&rt, &art, &warm, provider.as_mut())?;
    let opts = TrainOptions { log_every: 0, ..TrainOptions::new(0, n) };
    let res = train(&rt, &art, &opts, provider.as_mut())?;
    let step_ms = 1000.0 / res.steps_per_sec;

    // PTQ stages
    let params = res.params;
    let copts = CollectOptions { gamma: 0.0, zeta: 1.0, gate_scale: 1.0 };
    let mut calib_p = make_provider(&cfg, 1, Stream::Calibration);
    let t0 = Instant::now();
    let cal = calibrate(&rt, &art, &params, calib_p.as_mut(), 4, EstimatorKind::Percentile { pct: 99.999 }, &copts, 1)?;
    let calib_ms = t0.elapsed().as_secs_f64() * 1000.0 / 4.0;
    let t0 = Instant::now();
    std::hint::black_box(cal.finalize(8));
    let finalize_ms = t0.elapsed().as_secs_f64() * 1000.0;

    let mut eval_p = make_provider(&cfg, 0, Stream::Eval);
    let t0 = Instant::now();
    evaluate(&rt, &art, &params, eval_p.as_mut(), 4, 0.0, 1.0, 1.0)?;
    let eval_ms = t0.elapsed().as_secs_f64() * 1000.0 / 4.0;

    let t0 = Instant::now();
    quantized_eval(&rt, &art, &params, &QuantSpec { calib_batches: 4, ..QuantSpec::w8a8() }, 0.0, 1.0, 1.0, 4, 1)?;
    let ptq_ms = t0.elapsed().as_secs_f64() * 1000.0;

    println!("\n## L3 pipeline profile (bert_tiny, ms)\n");
    println!("batch synthesis        {batch_ms:9.3} /batch");
    println!("literal conversion     {lit_ms:9.3} /batch");
    println!("train_step total       {step_ms:9.3} /step   (execute+readback = total - batch - lit ≈ {:.3})", step_ms - batch_ms - lit_ms);
    println!("act_collect            {calib_ms:9.3} /batch");
    println!("range finalize (8b)    {finalize_ms:9.3} once");
    println!("eval_step              {eval_ms:9.3} /batch");
    println!("full PTQ (4+4 batches) {ptq_ms:9.3} once");
    Ok(())
}
