//! Serving throughput/latency: loadgen vs. server at batch sizes {1, 8, max}.
//!
//! Demonstrates the point of the dynamic batcher: with a per-dispatch
//! dominated engine (exactly the PJRT profile — compile once, pay per
//! launch), batched throughput must beat batch-size-1 throughput. Uses the
//! deterministic mock engine by default so the bench runs anywhere; set
//! QTX_BENCH_SERVE_COST_US to change the simulated per-dispatch cost
//! (default 3000µs ≈ a tiny-config serve_score invocation).
//!
//! Run: cargo bench --bench bench_serve
//! Env: QTX_BENCH_REQS     requests per client   (default 64)
//!      QTX_BENCH_CLIENTS  concurrent clients    (default 8)
//!      QTX_BENCH_SERVE_COST_US  mock per-dispatch cost (default 3000)
//!
//! Output: a markdown table (the repo's bench idiom) plus one
//! `bench_serve JSON: {...}` line per row for machine consumption.

use std::sync::Arc;
use std::time::Duration;

use qtx::metrics::table::render;
use qtx::serve::batcher::BatcherConfig;
use qtx::serve::engine::{EngineFactory, MockEngine, ScoreEngine};
use qtx::serve::loadgen::{self, LoadgenConfig};
use qtx::serve::server::{Client, EngineInfo, Server, ServerConfig};
use qtx::util::json::Json;

const SEQ_LEN: usize = 64;
const MODEL_BATCH: usize = 32; // "max" — the static batch of the mock model

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

struct Row {
    max_batch: usize,
    rps: f64,
    p50: f64,
    p95: f64,
    p99: f64,
    fill: f64,
}

fn bench_one(max_batch: usize, clients: usize, reqs: usize, cost_us: u64) -> anyhow::Result<Row> {
    let factory: EngineFactory = Arc::new(move || {
        let mut e = MockEngine::new(MODEL_BATCH, SEQ_LEN);
        e.batch_cost = Duration::from_micros(cost_us);
        Ok(Box::new(e) as Box<dyn ScoreEngine>)
    });
    let probe = MockEngine::new(MODEL_BATCH, SEQ_LEN);
    let server = Server::start(
        ServerConfig {
            host: "127.0.0.1".into(),
            port: 0,
            max_connections: clients + 8,
            engines: 1,
            batcher: BatcherConfig {
                max_batch,
                max_wait: Duration::from_millis(2),
                queue_cap: 1024,
            },
            request_timeout: Duration::from_secs(60),
        },
        EngineInfo {
            seq_len: SEQ_LEN,
            max_batch,
            vocab: 256,
            causal: probe.causal,
            describe: probe.describe(),
        },
        factory,
    )?;
    server.wait_ready(Duration::from_secs(10))?;
    let addr = server.addr().to_string();

    let report = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        clients,
        requests_per_client: reqs,
        vocab: 256,
        seq_len: SEQ_LEN,
        seed: 42,
        timeout: Duration::from_secs(60),
    })?;
    anyhow::ensure!(report.errors == 0, "loadgen errors: {}", report.errors);

    let mut c = Client::connect(&addr, Duration::from_secs(5))?;
    let statz = c.get_json("/statz")?;
    let fill = statz
        .req("batches")?
        .req("fill_ratio")?
        .as_f64()
        .unwrap_or(0.0);
    drop(c);
    server.stop();
    Ok(Row {
        max_batch,
        rps: report.throughput_rps,
        p50: report.p50_ms,
        p95: report.p95_ms,
        p99: report.p99_ms,
        fill,
    })
}

fn main() -> anyhow::Result<()> {
    let reqs = env_usize("QTX_BENCH_REQS", 64);
    let clients = env_usize("QTX_BENCH_CLIENTS", 8);
    let cost_us = env_usize("QTX_BENCH_SERVE_COST_US", 3000) as u64;

    let mut rows = Vec::new();
    for max_batch in [1usize, 8, MODEL_BATCH] {
        let r = bench_one(max_batch, clients, reqs, cost_us)?;
        eprintln!(
            "[bench_serve] max_batch={}: {:.1} req/s, p50 {:.2} ms, fill {:.2}",
            r.max_batch, r.rps, r.p50, r.fill
        );
        println!(
            "bench_serve JSON: {}",
            Json::obj(vec![
                ("max_batch", Json::Num(r.max_batch as f64)),
                ("clients", Json::Num(clients as f64)),
                ("requests", Json::Num((clients * reqs) as f64)),
                ("throughput_rps", Json::Num(r.rps)),
                ("p50_ms", Json::Num(r.p50)),
                ("p95_ms", Json::Num(r.p95)),
                ("p99_ms", Json::Num(r.p99)),
                ("batch_fill_ratio", Json::Num(r.fill)),
            ])
        );
        rows.push(r);
    }

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.max_batch.to_string(),
                format!("{:.1}", r.rps),
                format!("{:.2}", r.p50),
                format!("{:.2}", r.p95),
                format!("{:.2}", r.p99),
                format!("{:.2}", r.fill),
                format!("{:+.1}%", 100.0 * (r.rps - rows[0].rps) / rows[0].rps),
            ]
        })
        .collect();
    println!(
        "\n## serving throughput — dynamic batching, {clients} closed-loop clients (mock engine, {cost_us}µs/dispatch)\n\n{}",
        render(
            &["max batch", "req/s", "p50 ms", "p95 ms", "p99 ms", "fill", "vs bs=1"],
            &table
        )
    );

    let bs1 = rows[0].rps;
    let best = rows.last().unwrap().rps;
    anyhow::ensure!(
        best > bs1,
        "batched throughput ({best:.1} req/s) did not beat batch-size-1 ({bs1:.1} req/s)"
    );
    println!(
        "\nbatched vs bs=1 speedup: {:.1}x (fill ratio {:.2})",
        best / bs1,
        rows.last().unwrap().fill
    );
    Ok(())
}
