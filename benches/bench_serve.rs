//! Serving throughput/latency benches.
//!
//! Nine sections. All but the engine comparison run on the deterministic
//! mock engine (set QTX_BENCH_SERVE_COST_US to change the simulated
//! per-dispatch cost; default 3000µs ≈ a tiny-config serve_score
//! invocation):
//!
//! 1. **Closed loop, batch-size sweep** (the PR-1 trajectory): loadgen vs.
//!    server at max_batch {1, 8, 32}; batched throughput must beat
//!    batch-size-1 (the point of batching at all).
//! 2. **Open loop, policy × rate matrix** (the continuous-batching
//!    trajectory): fixed vs. continuous at Poisson arrival rates
//!    {0.5×, 1×, 2×} of engine capacity (max_batch / dispatch cost), plus
//!    a row at 1.5× of the *fixed batcher's batch-formation capacity*
//!    (max_batch / max_wait) — the convoy regime continuous batching
//!    removes. Expect continuous to win queue-wait p95 below engine
//!    saturation and to tie once both policies are backlog-bound past it.
//! 3. **Engine dimension: pjrt vs native-int8** (the PR-3 trajectory) —
//!    full-batch `score()` dispatch latency and rows/s for the f32
//!    fake-quant PJRT session against the native integer backend, on the
//!    same calibrated `bert_tiny_softmax` checkpoint. Needs
//!    `make artifacts`; skipped (with a note) otherwise, so CI's
//!    artifact-less `make bench` still completes.
//! 4. **Decode matrix** (the KV-cache decode trajectory): prefill len ×
//!    new tokens → generated tokens/s plus per-token and prefill p95
//!    from `/statz`'s decode histograms, over slot-pinned sessions on the
//!    continuous batcher.
//! 5. **Observability overhead**: closed-loop req/s and decode tok/s with
//!    request tracing on (`--trace-capacity 256`, the default) vs off
//!    (capacity 0), on the mock engine — the serving-layer span/ring cost
//!    in isolation. (Engine phase timers ride the native engine's forward
//!    and are always on; their cost is inside `engine_compare`'s numbers.)
//! 6. **Decode scaling** (the batched-decode trajectory): generated tok/s
//!    at {1, 4, 8, 16} concurrent sessions, batched multi-session decode
//!    vs the per-session GEMV loop (`QTX_DECODE=gemv`). The mock engine
//!    charges `step_cost` once per batched pass vs once per session, so
//!    the table isolates exactly the amortization the batched worker pass
//!    buys (docs/GENERATION.md "Batched decode").
//! 7. **Latency vs open connections** (the event-loop front-end
//!    trajectory): a fixed closed-loop score load measured while
//!    {16, 256, 1024} extra keep-alive connections sit idle on the
//!    single-threaded poll loop — p95 must stay flat because idle
//!    sockets cost a poll-set entry, not a thread.
//! 8. **Routing** (the multi-replica trajectory): score rows/s, p95 and
//!    decode tok/s through `qtx route` at {1, 2, 4} replicas, then a
//!    recovery drill — one of two replicas kills its front-end mid-run
//!    (`--fault kill-after:8`) and the row records detection time,
//!    half-open rejoin time and score retries; deliberate 503 sheds are
//!    tolerated, any other failure aborts the bench (zero lost requests,
//!    the docs/ROUTING.md contract).
//! 9. **Hot reload** (the operable-artifacts trajectory): closed-loop
//!    score load straight at one server and through `qtx route` over two
//!    replicas while `POST /admin/reload` swaps the weight generation
//!    mid-run — the row records admin round-trip time and the final
//!    `/statz` generation, and any lost request aborts the bench (the
//!    docs/ARTIFACTS.md zero-downtime contract).
//!
//! Run: cargo bench --bench bench_serve
//! Env: QTX_BENCH_REQS     closed-loop requests per client (default 64)
//!      QTX_BENCH_CLIENTS  closed-loop clients (default 8)
//!      QTX_BENCH_SENDERS  open-loop sender pool (default 96)
//!      QTX_BENCH_SERVE_COST_US  mock per-dispatch cost (default 3000)
//!      QTX_BENCH_ENGINE_ITERS   engine-compare dispatches (default 10)
//!      QTX_BENCH_GEN_REQS       decode sessions per client (default 8)
//!      QTX_BENCH_GEN_CLIENTS    decode closed-loop clients (default 8)
//!      QTX_BENCH_SCALE_REQS     decode-scaling sessions per client (default 4)
//!      QTX_BENCH_ROUTE_REQS     routing-section requests per client (default 16)
//!
//! Output: markdown tables (the repo's bench idiom) plus one
//! `bench_serve JSON: {...}` line per row — CI collects these lines into
//! `BENCH_serve.json` as the perf trajectory (see Makefile `bench`).

use std::sync::Arc;
use std::time::{Duration, Instant};

use qtx::infer::NativeInt8Engine;
use qtx::metrics::table::render;
use qtx::serve::batcher::{BatchPolicy, BatcherConfig};
use qtx::serve::engine::{EngineFactory, EngineSpec, MockEngine, PjrtEngine, ScoreEngine, WeightHub};
use qtx::serve::fault::FaultSpec;
use qtx::serve::loadgen::{self, ConnectionHold, GenLoad, LoadgenConfig, LoadgenReport};
use qtx::serve::obs::TraceConfig;
use qtx::serve::route::{Router, RouterConfig};
use qtx::serve::poll::raise_nofile_limit;
use qtx::serve::protocol::ScoreRequest;
use qtx::serve::server::{
    AdminHooks, Client, EngineInfo, ReloadFn, ReloadOutcome, Server, ServerConfig,
};
use qtx::serve::stats::{ArtifactId, EngineMem};
use qtx::util::json::Json;

const SEQ_LEN: usize = 64;
const MODEL_BATCH: usize = 32; // closed-loop "max" — the mock model's static batch
const MATRIX_BATCH: usize = 8; // open-loop matrix batch (keeps cell runtimes short)
// Fill-seeking flush deadline for the matrix, deliberately >> dispatch cost
// so the formation-capacity regime (8/20ms = 400 rps) sits well below
// engine capacity (8/3ms ≈ 2667 rps at the default cost) instead of
// overlapping it — the two regimes stay distinguishable for any sane
// QTX_BENCH_SERVE_COST_US.
const MATRIX_MAX_WAIT_MS: u64 = 20;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn start_server(
    policy: BatchPolicy,
    max_batch: usize,
    max_wait_ms: u64,
    queue_cap: usize,
    max_connections: usize,
    cost_us: u64,
    trace_capacity: usize,
) -> anyhow::Result<Server> {
    start_server_at(
        0,
        FaultSpec::default(),
        policy,
        max_batch,
        max_wait_ms,
        queue_cap,
        max_connections,
        cost_us,
        trace_capacity,
    )
}

/// `start_server` with an explicit port (0 = ephemeral; the routing
/// recovery bench restarts a replica at its advertised address) and a
/// fault spec (the routing section drills `kill-after`).
#[allow(clippy::too_many_arguments)]
fn start_server_at(
    port: u16,
    fault: FaultSpec,
    policy: BatchPolicy,
    max_batch: usize,
    max_wait_ms: u64,
    queue_cap: usize,
    max_connections: usize,
    cost_us: u64,
    trace_capacity: usize,
) -> anyhow::Result<Server> {
    let factory: EngineFactory = Arc::new(move || {
        let mut e = MockEngine::new(max_batch.max(MODEL_BATCH), SEQ_LEN);
        e.batch_cost = Duration::from_micros(cost_us);
        Ok(Box::new(e) as Box<dyn ScoreEngine>)
    });
    let probe = MockEngine::new(max_batch.max(MODEL_BATCH), SEQ_LEN);
    let server = Server::start(
        ServerConfig {
            host: "127.0.0.1".into(),
            port,
            max_connections,
            engines: 1,
            policy,
            batcher: BatcherConfig {
                max_batch,
                max_wait: Duration::from_millis(max_wait_ms),
                queue_cap,
            },
            admit_window: Duration::ZERO,
            read_timeout: Duration::from_secs(60),
            request_timeout: Duration::from_secs(60),
            trace: TraceConfig { capacity: trace_capacity, slow_ms: 0 },
            fault,
        },
        EngineInfo {
            seq_len: SEQ_LEN,
            max_batch,
            vocab: 256,
            causal: probe.causal,
            decode: true,
            describe: probe.describe(),
            mem: EngineMem::default(),
            gemm_threads: 1,
        },
        factory,
    )?;
    server.wait_ready(Duration::from_secs(10))?;
    Ok(server)
}

fn fill_ratio(addr: &str) -> anyhow::Result<f64> {
    let mut c = Client::connect(addr, Duration::from_secs(5))?;
    let statz = c.get_json("/statz")?;
    Ok(statz.req("batches")?.req("fill_ratio")?.as_f64().unwrap_or(0.0))
}

// ---------------------------------------------------------------------------
// Section 1: closed loop, batch-size sweep (fixed policy, PR-1 trajectory)
// ---------------------------------------------------------------------------

struct ClosedRow {
    max_batch: usize,
    rps: f64,
    p50: f64,
    p95: f64,
    p99: f64,
    fill: f64,
}

fn bench_closed(
    max_batch: usize,
    clients: usize,
    reqs: usize,
    cost_us: u64,
) -> anyhow::Result<ClosedRow> {
    let server = start_server(BatchPolicy::Fixed, max_batch, 2, 1024, clients + 8, cost_us, 256)?;
    let addr = server.addr().to_string();
    let report = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        clients,
        requests_per_client: reqs,
        vocab: 256,
        seq_len: SEQ_LEN,
        seed: 42,
        timeout: Duration::from_secs(60),
        open_rate_rps: None,
        gen: None,
    })?;
    anyhow::ensure!(report.errors == 0, "loadgen errors: {}", report.errors);
    let fill = fill_ratio(&addr)?;
    server.stop();
    Ok(ClosedRow {
        max_batch,
        rps: report.throughput_rps,
        p50: report.p50_ms,
        p95: report.p95_ms,
        p99: report.p99_ms,
        fill,
    })
}

// ---------------------------------------------------------------------------
// Section 2: open loop, policy × arrival-rate matrix
// ---------------------------------------------------------------------------

struct MatrixRow {
    policy: BatchPolicy,
    label: String,
    rate: f64,
    report: LoadgenReport,
    fill: f64,
}

fn bench_open(
    policy: BatchPolicy,
    label: &str,
    rate: f64,
    senders: usize,
    cost_us: u64,
) -> anyhow::Result<MatrixRow> {
    let server = start_server(
        policy,
        MATRIX_BATCH,
        MATRIX_MAX_WAIT_MS,
        4096,
        senders + 8,
        cost_us,
        256,
    )?;
    let addr = server.addr().to_string();
    // ~1 s of offered load per cell, bounded so overload cells stay short.
    let total = (rate as usize).clamp(256, 4096);
    let report = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        clients: senders,
        // Round the per-sender share up; the schedule length is what counts.
        requests_per_client: total / senders + 1,
        vocab: 256,
        seq_len: SEQ_LEN,
        seed: 42,
        timeout: Duration::from_secs(60),
        open_rate_rps: Some(rate),
        gen: None,
    })?;
    anyhow::ensure!(report.ok > 0, "no successful requests ({} errors)", report.errors);
    let fill = fill_ratio(&addr)?;
    server.stop();
    Ok(MatrixRow { policy, label: label.to_string(), rate, report, fill })
}

// ---------------------------------------------------------------------------
// Section 4: decode matrix — prefill len × new tokens (mock engine)
// ---------------------------------------------------------------------------

struct DecodeRow {
    prefill_len: usize,
    new_tokens: usize,
    ok: u64,
    errors: u64,
    tokens_per_s: f64,
    step_p95_ms: f64,
    prefill_p95_ms: f64,
}

/// Closed-loop generate sessions through the continuous batcher: tokens/s
/// from the loadgen report, per-token and prefill p95 from `/statz`'s
/// decode histograms.
fn bench_decode(
    prefill_len: usize,
    new_tokens: usize,
    clients: usize,
    reqs: usize,
    cost_us: u64,
) -> anyhow::Result<DecodeRow> {
    let server = start_server(
        BatchPolicy::Continuous,
        MATRIX_BATCH,
        MATRIX_MAX_WAIT_MS,
        1024,
        clients + 8,
        cost_us,
        256,
    )?;
    let addr = server.addr().to_string();
    let report = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        clients,
        requests_per_client: reqs,
        vocab: 256,
        seq_len: SEQ_LEN,
        seed: 42,
        timeout: Duration::from_secs(60),
        open_rate_rps: None,
        gen: Some(qtx::serve::loadgen::GenLoad::greedy(new_tokens, prefill_len)),
    })?;
    anyhow::ensure!(report.errors == 0, "decode loadgen errors: {}", report.errors);
    let mut c = Client::connect(&addr, Duration::from_secs(5))?;
    let statz = c.get_json("/statz")?;
    let decode = statz.req("decode")?;
    let p95 = |k: &str| -> anyhow::Result<f64> {
        Ok(decode.req(k)?.req("p95_ms")?.as_f64().unwrap_or(0.0))
    };
    let row = DecodeRow {
        prefill_len,
        new_tokens,
        ok: report.ok,
        errors: report.errors,
        tokens_per_s: report.gen_tokens_per_s,
        step_p95_ms: p95("step")?,
        prefill_p95_ms: p95("prefill")?,
    };
    drop(c);
    server.stop();
    Ok(row)
}

// ---------------------------------------------------------------------------
// Section 6: decode scaling — batched multi-session step vs GEMV loop
// ---------------------------------------------------------------------------

struct ScaleRow {
    mode: &'static str,
    sessions: usize,
    tokens_per_s: f64,
    inter_token_p95_ms: f64,
}

/// One decode-scaling cell: `sessions` closed-loop clients each running
/// back-to-back generation sessions, so up to `sessions` slots decode
/// concurrently. `gemv: true` sets `QTX_DECODE=gemv` for the server's
/// lifetime (the worker reads it once at startup), forcing the per-session
/// step loop the batched pass replaced.
fn bench_decode_scale(
    sessions: usize,
    gemv: bool,
    reqs: usize,
    cost_us: u64,
) -> anyhow::Result<ScaleRow> {
    if gemv {
        std::env::set_var("QTX_DECODE", "gemv");
    }
    let run = || -> anyhow::Result<ScaleRow> {
        let server = start_server(
            BatchPolicy::Continuous,
            MODEL_BATCH,
            MATRIX_MAX_WAIT_MS,
            1024,
            sessions + 8,
            cost_us,
            0,
        )?;
        let addr = server.addr().to_string();
        let report = loadgen::run(&LoadgenConfig {
            addr: addr.clone(),
            clients: sessions,
            requests_per_client: reqs,
            vocab: 256,
            seq_len: SEQ_LEN,
            seed: 42,
            timeout: Duration::from_secs(60),
            open_rate_rps: None,
            gen: Some(qtx::serve::loadgen::GenLoad::greedy(24, 8)),
        })?;
        anyhow::ensure!(report.errors == 0, "decode_scaling loadgen errors: {}", report.errors);
        let mut c = Client::connect(&addr, Duration::from_secs(5))?;
        let statz = c.get_json("/statz")?;
        let inter_p95 = statz
            .req("decode")?
            .req("inter_token")?
            .req("p95_ms")?
            .as_f64()
            .unwrap_or(0.0);
        drop(c);
        server.stop();
        Ok(ScaleRow {
            mode: if gemv { "gemv" } else { "batched" },
            sessions,
            tokens_per_s: report.gen_tokens_per_s,
            inter_token_p95_ms: inter_p95,
        })
    };
    let row = run();
    // Always restore the env — the batched cells (and later sections) must
    // not inherit the GEMV escape hatch.
    if gemv {
        std::env::remove_var("QTX_DECODE");
    }
    row
}

// ---------------------------------------------------------------------------
// Section 5: observability overhead — request tracing on vs off
// ---------------------------------------------------------------------------

struct ObsRow {
    mode: &'static str,
    rps: f64,
    tokens_per_s: f64,
}

/// One closed-loop scoring run and one closed-loop decode run on a server
/// with the given trace-ring capacity (0 = tracing disabled).
fn bench_obs(
    mode: &'static str,
    trace_capacity: usize,
    clients: usize,
    reqs: usize,
    cost_us: u64,
) -> anyhow::Result<ObsRow> {
    let common = |gen| LoadgenConfig {
        addr: String::new(),
        clients,
        requests_per_client: reqs,
        vocab: 256,
        seq_len: SEQ_LEN,
        seed: 42,
        timeout: Duration::from_secs(60),
        open_rate_rps: None,
        gen,
    };
    let server = start_server(
        BatchPolicy::Continuous,
        MATRIX_BATCH,
        MATRIX_MAX_WAIT_MS,
        1024,
        clients + 8,
        cost_us,
        trace_capacity,
    )?;
    let score = loadgen::run(&LoadgenConfig { addr: server.addr().to_string(), ..common(None) })?;
    anyhow::ensure!(score.errors == 0, "obs score loadgen errors: {}", score.errors);
    server.stop();

    let server = start_server(
        BatchPolicy::Continuous,
        MATRIX_BATCH,
        MATRIX_MAX_WAIT_MS,
        1024,
        clients + 8,
        cost_us,
        trace_capacity,
    )?;
    let gen = loadgen::run(&LoadgenConfig {
        addr: server.addr().to_string(),
        ..common(Some(qtx::serve::loadgen::GenLoad::greedy(16, 8)))
    })?;
    anyhow::ensure!(gen.errors == 0, "obs decode loadgen errors: {}", gen.errors);
    server.stop();
    Ok(ObsRow { mode, rps: score.throughput_rps, tokens_per_s: gen.gen_tokens_per_s })
}

// ---------------------------------------------------------------------------
// Section 7: latency vs open connections (event-loop front-end)
// ---------------------------------------------------------------------------

struct ConnRow {
    held: usize,
    rps: f64,
    p50: f64,
    p95: f64,
    io_threads: usize,
}

/// A fixed closed-loop score load measured while `held` extra keep-alive
/// connections sit idle on the event loop, with an occasional trickle
/// request proving they are serviceable, not just open. The p95-vs-held
/// curve is the front-end's scalability claim: an idle connection costs
/// a poll-set entry, not a thread.
fn bench_connections(
    held: usize,
    clients: usize,
    reqs: usize,
    cost_us: u64,
) -> anyhow::Result<ConnRow> {
    let server = start_server(
        BatchPolicy::Continuous,
        MATRIX_BATCH,
        MATRIX_MAX_WAIT_MS,
        1024,
        held + clients + 16,
        cost_us,
        0,
    )?;
    let addr = server.addr().to_string();
    let mut hold = ConnectionHold::open(&addr, held, Duration::from_secs(10))?;
    let score = ScoreRequest { id: None, tokens: vec![1, 2, 3, 4], targets: None }.to_json();
    for i in 0..held.min(16) {
        let status = hold.trickle(i * 61, "POST", "/v1/score", Some(&score))?;
        anyhow::ensure!(status == 200, "trickle over a held connection got {status}");
    }
    let report = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        clients,
        requests_per_client: reqs,
        vocab: 256,
        seq_len: SEQ_LEN,
        seed: 42,
        timeout: Duration::from_secs(60),
        open_rate_rps: None,
        gen: None,
    })?;
    anyhow::ensure!(report.errors == 0, "connection-sweep loadgen errors: {}", report.errors);
    let mut c = Client::connect(&addr, Duration::from_secs(5))?;
    let statz = c.get_json("/statz")?;
    let io_threads = statz.req("server")?.req("io_threads")?.as_usize().unwrap_or(0);
    let open = statz.req("connections")?.req("open")?.as_usize().unwrap_or(0);
    anyhow::ensure!(open >= held, "expected >= {held} open connections, /statz says {open}");
    drop(c);
    drop(hold);
    server.stop();
    Ok(ConnRow {
        held,
        rps: report.throughput_rps,
        p50: report.p50_ms,
        p95: report.p95_ms,
        io_threads,
    })
}

// ---------------------------------------------------------------------------
// Section 8: routing — `qtx route` fronting 1/2/4 replicas + fault recovery
// ---------------------------------------------------------------------------

struct RouteRow {
    replicas: usize,
    rps: f64,
    p95: f64,
    shed: u64,
    tok_s: f64,
}

/// Router with bench-speed probe cadence over `n` continuous replicas.
fn start_fleet(n: usize, cost_us: u64) -> anyhow::Result<(Vec<Server>, Router, String)> {
    let mut servers = Vec::new();
    for _ in 0..n {
        servers.push(start_server(BatchPolicy::Continuous, MATRIX_BATCH, 5, 128, 256, cost_us, 0)?);
    }
    let router = Router::start(RouterConfig {
        backends: servers.iter().map(|s| s.addr().to_string()).collect(),
        probe_interval: Duration::from_millis(25),
        eject_after: 2,
        halfopen_interval: Duration::from_millis(50),
        retry_backoff: Duration::from_millis(5),
        ..RouterConfig::default()
    })?;
    anyhow::ensure!(router.wait_ready(Duration::from_secs(10)), "no replica came up");
    let addr = router.addr().to_string();
    Ok((servers, router, addr))
}

/// Deliberate sheds (503, counted as `http_503`) are the admission
/// contract under saturation — anything else (resets, 502s, timeouts)
/// is a lost request and fails the bench.
fn ensure_only_shed(r: &LoadgenReport, label: &str) -> anyhow::Result<()> {
    anyhow::ensure!(r.ok > 0, "{label}: no successful requests ({:?})", r.errors_by_cause);
    for (cause, n) in &r.errors_by_cause {
        anyhow::ensure!(cause == "http_503", "{label}: non-shed failures: {cause}={n}");
    }
    Ok(())
}

fn route_statz(addr: &str) -> anyhow::Result<Json> {
    let mut c = Client::connect(addr, Duration::from_secs(5))?;
    c.get_json("/statz")
}

fn route_num(statz: &Json, dotted: &str) -> anyhow::Result<f64> {
    let mut cur = statz;
    for part in dotted.split('.') {
        cur = cur.req(part)?;
    }
    cur.as_f64().ok_or_else(|| anyhow::anyhow!("{dotted} not a number"))
}

/// Poll the router's `/statz` until `pred` holds; returns how long it
/// took (the observable the recovery row is built from).
fn wait_route(addr: &str, what: &str, pred: impl Fn(&Json) -> bool) -> anyhow::Result<Duration> {
    let t0 = Instant::now();
    loop {
        let statz = route_statz(addr)?;
        if pred(&statz) {
            return Ok(t0.elapsed());
        }
        anyhow::ensure!(t0.elapsed() < Duration::from_secs(10), "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Score rows/s and decode tok/s through the router at `n` replicas —
/// the fleet-scaling trajectory a single `qtx serve` cannot offer.
fn bench_route_scale(
    n: usize,
    clients: usize,
    reqs: usize,
    cost_us: u64,
) -> anyhow::Result<RouteRow> {
    let (servers, router, addr) = start_fleet(n, cost_us)?;
    let score = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        clients,
        requests_per_client: reqs,
        vocab: 256,
        seq_len: SEQ_LEN,
        seed: 42,
        timeout: Duration::from_secs(60),
        open_rate_rps: None,
        gen: None,
    })?;
    ensure_only_shed(&score, "route score")?;
    let gen = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        clients: clients.min(4),
        requests_per_client: 4,
        vocab: 256,
        seq_len: SEQ_LEN,
        seed: 43,
        timeout: Duration::from_secs(60),
        open_rate_rps: None,
        gen: Some(GenLoad::greedy(16, 8)),
    })?;
    ensure_only_shed(&gen, "route decode")?;
    router.stop();
    for s in servers {
        s.stop();
    }
    Ok(RouteRow {
        replicas: n,
        rps: score.throughput_rps,
        p95: score.p95_ms,
        shed: score.errors + gen.errors,
        tok_s: gen.gen_tokens_per_s,
    })
}

struct RecoveryRow {
    requests: u64,
    retries: f64,
    detect_ms: f64,
    rejoin_ms: f64,
}

/// The fault drill as a measurement: how fast the router notices a
/// killed replica (detect = run start → ejection observed) and how fast
/// an ejected replica folds back in through the half-open probe (rejoin
/// = replica restart → census Up). Requests lost across the kill: zero
/// tolerated, same bar as the e2e test.
fn bench_route_recovery(clients: usize, reqs: usize, cost_us: u64) -> anyhow::Result<RecoveryRow> {
    // Phase 1 — detection: one of two replicas kills its front-end after
    // its 8th dispatched request, mid-run.
    let healthy = start_server(BatchPolicy::Continuous, MATRIX_BATCH, 5, 128, 256, cost_us, 0)?;
    let doomed = start_server_at(
        0,
        FaultSpec::parse("kill-after:8").expect("static spec"),
        BatchPolicy::Continuous,
        MATRIX_BATCH,
        5,
        128,
        256,
        cost_us,
        0,
    )?;
    let router = Router::start(RouterConfig {
        backends: vec![healthy.addr().to_string(), doomed.addr().to_string()],
        probe_interval: Duration::from_millis(25),
        eject_after: 2,
        halfopen_interval: Duration::from_millis(50),
        retry_backoff: Duration::from_millis(5),
        ..RouterConfig::default()
    })?;
    anyhow::ensure!(router.wait_ready(Duration::from_secs(10)), "fleet never came up");
    let addr = router.addr().to_string();
    let t0 = Instant::now();
    let report = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        clients,
        requests_per_client: reqs,
        vocab: 256,
        seq_len: SEQ_LEN,
        seed: 44,
        timeout: Duration::from_secs(60),
        open_rate_rps: None,
        gen: None,
    })?;
    ensure_only_shed(&report, "route recovery")?;
    wait_route(&addr, "ejection", |s| {
        route_num(s, "route.replicas.ejected").unwrap_or(0.0) == 1.0
    })?;
    // detect = run start → ejection visible in the census. The kill fires
    // mid-run, so this folds in the requests served before the fault; it
    // is the client-observable outage window, not the probe latency.
    let detect_ms = t0.elapsed().as_secs_f64() * 1000.0;
    let statz = route_statz(&addr)?;
    let retries = route_num(&statz, "route.requests.retries")?;
    router.stop();
    healthy.stop();
    doomed.stop();

    // Phase 2 — rejoin: a replica address with nothing listening ejects,
    // then a server starts there; the half-open probe folds it back in.
    let live = start_server(BatchPolicy::Continuous, MATRIX_BATCH, 5, 128, 256, cost_us, 0)?;
    let reserved = {
        let l = std::net::TcpListener::bind("127.0.0.1:0")?;
        l.local_addr()?
    };
    let router = Router::start(RouterConfig {
        backends: vec![live.addr().to_string(), reserved.to_string()],
        probe_interval: Duration::from_millis(25),
        eject_after: 2,
        halfopen_interval: Duration::from_millis(50),
        ..RouterConfig::default()
    })?;
    let addr = router.addr().to_string();
    wait_route(&addr, "dead-address ejection", |s| {
        route_num(s, "route.replicas.ejected").unwrap_or(0.0) == 1.0
    })?;
    let t1 = Instant::now();
    let revived = start_server_at(
        reserved.port(),
        FaultSpec::default(),
        BatchPolicy::Continuous,
        MATRIX_BATCH,
        5,
        128,
        256,
        cost_us,
        0,
    )?;
    wait_route(&addr, "replica rejoin", |s| {
        route_num(s, "route.replicas.up").unwrap_or(0.0) == 2.0
    })?;
    // rejoin = replica restart → census Up (server startup + half-open
    // probe success), the window where the fleet runs a replica short.
    let rejoin_ms = t1.elapsed().as_secs_f64() * 1000.0;
    router.stop();
    live.stop();
    revived.stop();
    Ok(RecoveryRow { requests: report.sent, retries, detect_ms, rejoin_ms })
}

// ---------------------------------------------------------------------------
// Section 9: hot reload — /admin/reload under closed-loop load
// ---------------------------------------------------------------------------

struct ReloadRow {
    mode: &'static str,
    requests: u64,
    rps: f64,
    p95: f64,
    reloads: u64,
    reload_ms: f64,
    generation: f64,
}

/// A continuous-batching replica whose mock engine tracks a `WeightHub`,
/// with the admin reload hook wired: each `POST /admin/reload` publishes a
/// fresh weight generation (the mock stand-in for the native engine's
/// `load_weights` + `hub.publish` path in `qtx serve`).
fn start_reload_server(cost_us: u64) -> anyhow::Result<Server> {
    let hub = Arc::new(WeightHub::new(Arc::new(())));
    let factory: EngineFactory = {
        let hub = hub.clone();
        Arc::new(move || {
            let mut e = MockEngine::new(MODEL_BATCH, SEQ_LEN).with_hub(hub.clone());
            e.batch_cost = Duration::from_micros(cost_us);
            Ok(Box::new(e) as Box<dyn ScoreEngine>)
        })
    };
    let reload: ReloadFn = {
        let hub = hub.clone();
        Arc::new(move |_dir| {
            Ok(ReloadOutcome { generation: hub.publish(Arc::new(())), artifact: None })
        })
    };
    let probe = MockEngine::new(MODEL_BATCH, SEQ_LEN);
    let server = Server::start_with_admin(
        ServerConfig {
            host: "127.0.0.1".into(),
            port: 0,
            max_connections: 256,
            engines: 1,
            policy: BatchPolicy::Continuous,
            batcher: BatcherConfig {
                max_batch: MATRIX_BATCH,
                max_wait: Duration::from_millis(5),
                queue_cap: 1024,
            },
            admit_window: Duration::ZERO,
            read_timeout: Duration::from_secs(60),
            request_timeout: Duration::from_secs(60),
            trace: TraceConfig { capacity: 0, slow_ms: 0 },
            fault: FaultSpec::default(),
        },
        EngineInfo {
            seq_len: SEQ_LEN,
            max_batch: MATRIX_BATCH,
            vocab: 256,
            causal: probe.causal,
            decode: true,
            describe: probe.describe(),
            mem: EngineMem::default(),
            gemm_threads: 1,
        },
        factory,
        AdminHooks {
            reload: Some(reload),
            artifact: Some(ArtifactId {
                schema: 2,
                install_id: "bench-seed".into(),
                sha256_short: "0123456789ab".into(),
            }),
        },
    )?;
    server.wait_ready(Duration::from_secs(10))?;
    Ok(server)
}

/// The zero-downtime contract as a measurement: closed-loop score load —
/// straight at one replica (`routed = false`) or through `qtx route` over
/// two (`routed = true`) — while `/admin/reload` swaps the weight
/// generation mid-run, twice. Any lost request aborts the bench; the row
/// records the admin round-trip and the final `/statz` generation.
fn bench_reload(
    routed: bool,
    clients: usize,
    reqs: usize,
    cost_us: u64,
) -> anyhow::Result<ReloadRow> {
    let mut servers = vec![start_reload_server(cost_us)?];
    let mut router = None;
    let addr = if routed {
        servers.push(start_reload_server(cost_us)?);
        let r = Router::start(RouterConfig {
            backends: servers.iter().map(|s| s.addr().to_string()).collect(),
            probe_interval: Duration::from_millis(25),
            eject_after: 2,
            halfopen_interval: Duration::from_millis(50),
            retry_backoff: Duration::from_millis(5),
            ..RouterConfig::default()
        })?;
        anyhow::ensure!(r.wait_ready(Duration::from_secs(10)), "reload fleet never came up");
        let addr = r.addr().to_string();
        router = Some(r);
        addr
    } else {
        servers[0].addr().to_string()
    };
    // Reloads always target replica 0 directly: /admin is a per-replica
    // surface, not a routed one.
    let admin_addr = servers[0].addr().to_string();

    let load_cfg = LoadgenConfig {
        addr: addr.clone(),
        clients,
        requests_per_client: reqs,
        vocab: 256,
        seq_len: SEQ_LEN,
        seed: 45,
        timeout: Duration::from_secs(60),
        open_rate_rps: None,
        gen: None,
    };
    let load = std::thread::spawn(move || loadgen::run(&load_cfg));

    let n_reloads = 2u64;
    let mut admin_ms = 0.0;
    let mut last_gen = 0.0;
    let body = Json::obj(vec![("dir", Json::Str("/tmp/qtx-bench-reload".into()))]);
    for _ in 0..n_reloads {
        std::thread::sleep(Duration::from_millis(30));
        let t0 = Instant::now();
        let mut c = Client::connect(&admin_addr, Duration::from_secs(10))?;
        let (status, resp) = c.request("POST", "/admin/reload", Some(&body))?;
        admin_ms += t0.elapsed().as_secs_f64() * 1000.0;
        anyhow::ensure!(status == 200, "/admin/reload: status {status}: {resp}");
        let j = Json::parse(&resp).map_err(|e| anyhow::anyhow!("reload response: {e}"))?;
        last_gen = j.req("generation")?.as_f64().unwrap_or(0.0);
    }

    let report = load.join().expect("reload loadgen thread panicked")?;
    if routed {
        ensure_only_shed(&report, "reload routed")?;
    } else {
        anyhow::ensure!(
            report.errors == 0,
            "reload drill lost requests: {:?}",
            report.errors_by_cause
        );
    }

    let mut c = Client::connect(&admin_addr, Duration::from_secs(5))?;
    let statz = c.get_json("/statz")?;
    let weights = statz.req("weights")?;
    let generation = weights.req("generation")?.as_f64().unwrap_or(0.0);
    let reloads = weights.req("reloads")?.as_f64().unwrap_or(0.0) as u64;
    anyhow::ensure!(
        generation == last_gen && reloads == n_reloads,
        "statz says generation {generation} / reloads {reloads}, expected {last_gen} / {n_reloads}"
    );
    drop(c);
    if let Some(r) = router {
        r.stop();
    }
    for s in servers {
        s.stop();
    }
    Ok(ReloadRow {
        mode: if routed { "routed" } else { "direct" },
        requests: report.sent,
        rps: report.throughput_rps,
        p95: report.p95_ms,
        reloads: n_reloads,
        reload_ms: admin_ms / n_reloads as f64,
        generation,
    })
}

// ---------------------------------------------------------------------------
// Section 3: engine dimension — pjrt (fake-quant f32) vs native-int8
// ---------------------------------------------------------------------------

struct EngineRow {
    engine: &'static str,
    config: String,
    max_batch: usize,
    seq_len: usize,
    dispatch_ms: f64,
    rows_per_s: f64,
}

/// Time full-batch `score()` dispatches on an already-built engine.
fn bench_engine(
    engine: &mut dyn ScoreEngine,
    name: &'static str,
    config: &str,
    iters: usize,
) -> anyhow::Result<EngineRow> {
    let (bsz, t) = (engine.max_batch(), engine.seq_len());
    let reqs: Vec<ScoreRequest> = (0..bsz)
        .map(|i| ScoreRequest {
            id: None,
            tokens: (0..t).map(|j| ((i * 31 + j * 13) % 256) as i32).collect(),
            targets: None,
        })
        .collect();
    engine.score(&reqs)?; // warm-up (upload/lazy init out of the timing)
    let t0 = Instant::now();
    for _ in 0..iters {
        engine.score(&reqs)?;
    }
    let el = t0.elapsed().as_secs_f64();
    Ok(EngineRow {
        engine: name,
        config: config.to_string(),
        max_batch: bsz,
        seq_len: t,
        dispatch_ms: el / iters as f64 * 1e3,
        rows_per_s: (iters * bsz) as f64 / el,
    })
}

/// `Ok(None)` when artifacts/checkpoint are absent (CI runs bench without
/// `make artifacts`); errors are real once the inputs exist. The recipe is
/// [`EngineSpec::tiny_test_recipe`] — the exact configuration the
/// `serve_native` parity tests certify.
fn engine_compare(iters: usize) -> anyhow::Result<Option<Vec<EngineRow>>> {
    let spec = match EngineSpec::tiny_test_recipe() {
        Ok(spec) => spec,
        Err(why) => {
            eprintln!("[bench_serve] engine_compare skipped: {why}");
            return Ok(None);
        }
    };
    // Engines are built (and dropped) sequentially: PJRT handles are not
    // Send and each construction runs its own calibration pass.
    let mut rows = Vec::new();
    {
        let mut pjrt = PjrtEngine::new(&spec)?;
        rows.push(bench_engine(&mut pjrt, "pjrt", &spec.config, iters)?);
    }
    {
        let mut native = NativeInt8Engine::new(&spec)?;
        rows.push(bench_engine(&mut native, "native-int8", &spec.config, iters)?);
    }
    Ok(Some(rows))
}

fn main() -> anyhow::Result<()> {
    let reqs = env_usize("QTX_BENCH_REQS", 64);
    let clients = env_usize("QTX_BENCH_CLIENTS", 8);
    // Open-loop senders: must cover offered rate × latency or lag_p95_ms
    // shows the pool saturating (expected in the 2x overload cell).
    let senders = env_usize("QTX_BENCH_SENDERS", 96).max(1);
    let cost_us = env_usize("QTX_BENCH_SERVE_COST_US", 3000) as u64;

    // -- closed loop ---------------------------------------------------------
    let mut rows = Vec::new();
    for max_batch in [1usize, 8, MODEL_BATCH] {
        let r = bench_closed(max_batch, clients, reqs, cost_us)?;
        eprintln!(
            "[bench_serve] closed max_batch={}: {:.1} req/s, p50 {:.2} ms, fill {:.2}",
            r.max_batch, r.rps, r.p50, r.fill
        );
        println!(
            "bench_serve JSON: {}",
            Json::obj(vec![
                ("section", Json::Str("closed_batch_sweep".into())),
                ("policy", Json::Str("fixed".into())),
                ("max_batch", Json::Num(r.max_batch as f64)),
                ("clients", Json::Num(clients as f64)),
                ("requests", Json::Num((clients * reqs) as f64)),
                ("throughput_rps", Json::Num(r.rps)),
                ("p50_ms", Json::Num(r.p50)),
                ("p95_ms", Json::Num(r.p95)),
                ("p99_ms", Json::Num(r.p99)),
                ("batch_fill_ratio", Json::Num(r.fill)),
            ])
        );
        rows.push(r);
    }

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.max_batch.to_string(),
                format!("{:.1}", r.rps),
                format!("{:.2}", r.p50),
                format!("{:.2}", r.p95),
                format!("{:.2}", r.p99),
                format!("{:.2}", r.fill),
                format!("{:+.1}%", 100.0 * (r.rps - rows[0].rps) / rows[0].rps),
            ]
        })
        .collect();
    println!(
        "\n## serving throughput — dynamic batching, {clients} closed-loop clients (mock engine, {cost_us}µs/dispatch)\n\n{}",
        render(
            &["max batch", "req/s", "p50 ms", "p95 ms", "p99 ms", "fill", "vs bs=1"],
            &table
        )
    );

    let bs1 = rows[0].rps;
    let best = rows.last().unwrap().rps;
    anyhow::ensure!(
        best > bs1,
        "batched throughput ({best:.1} req/s) did not beat batch-size-1 ({bs1:.1} req/s)"
    );
    println!(
        "\nbatched vs bs=1 speedup: {:.1}x (fill ratio {:.2})",
        best / bs1,
        rows.last().unwrap().fill
    );

    // -- open-loop policy × rate matrix --------------------------------------
    let engine_cap = MATRIX_BATCH as f64 / (cost_us as f64 / 1e6);
    let formation_cap = MATRIX_BATCH as f64 / (MATRIX_MAX_WAIT_MS as f64 / 1e3);
    let cells: Vec<(String, f64)> = vec![
        ("1.5x formation".into(), 1.5 * formation_cap),
        ("0.5x engine".into(), 0.5 * engine_cap),
        ("1.0x engine".into(), 1.0 * engine_cap),
        ("2.0x engine".into(), 2.0 * engine_cap),
    ];
    let mut matrix = Vec::new();
    for (label, rate) in &cells {
        for policy in [BatchPolicy::Fixed, BatchPolicy::Continuous] {
            let row = bench_open(policy, label, *rate, senders, cost_us)?;
            eprintln!(
                "[bench_serve] open {} {}: q p95 {:.2} ms, {:.1} req/s ({} shed)",
                row.policy.name(),
                row.label,
                row.report.queue_p95_ms,
                row.report.throughput_rps,
                row.report.errors
            );
            println!(
                "bench_serve JSON: {}",
                Json::obj(vec![
                    ("section", Json::Str("open_policy_matrix".into())),
                    ("policy", Json::Str(row.policy.name().into())),
                    ("rate_label", Json::Str(row.label.clone())),
                    ("offered_rps", Json::Num(row.rate)),
                    ("engine_capacity_rps", Json::Num(engine_cap)),
                    ("formation_capacity_rps", Json::Num(formation_cap)),
                    ("ok", Json::Num(row.report.ok as f64)),
                    ("errors", Json::Num(row.report.errors as f64)),
                    ("throughput_rps", Json::Num(row.report.throughput_rps)),
                    ("p50_ms", Json::Num(row.report.p50_ms)),
                    ("p95_ms", Json::Num(row.report.p95_ms)),
                    ("p99_ms", Json::Num(row.report.p99_ms)),
                    ("queue_p50_ms", Json::Num(row.report.queue_p50_ms)),
                    ("queue_p95_ms", Json::Num(row.report.queue_p95_ms)),
                    ("lag_p95_ms", Json::Num(row.report.lag_p95_ms)),
                    ("batch_fill_ratio", Json::Num(row.fill)),
                ])
            );
            matrix.push(row);
        }
    }

    let mtable: Vec<Vec<String>> = matrix
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                r.policy.name().to_string(),
                format!("{:.0}", r.rate),
                format!("{:.1}", r.report.throughput_rps),
                format!("{:.2}", r.report.queue_p50_ms),
                format!("{:.2}", r.report.queue_p95_ms),
                format!("{:.2}", r.report.p95_ms),
                format!("{:.2}", r.fill),
                r.report.errors.to_string(),
            ]
        })
        .collect();
    println!(
        "\n## fixed vs continuous — open-loop Poisson arrivals (batch {MATRIX_BATCH}, \
         max_wait {MATRIX_MAX_WAIT_MS} ms, {cost_us}µs/dispatch, engine cap {engine_cap:.0} req/s)\n\n{}",
        render(
            &[
                "arrival rate", "policy", "req/s off.", "req/s", "q p50 ms", "q p95 ms",
                "p95 ms", "fill", "shed"
            ],
            &mtable
        )
    );
    println!(
        "\ncontinuous wins queue-wait below engine saturation; past it both policies are \
         backlog-bound (see ROADMAP Serving)."
    );

    // -- decode matrix: prefill len × new tokens -----------------------------
    let gen_reqs = env_usize("QTX_BENCH_GEN_REQS", 8);
    let gen_clients = env_usize("QTX_BENCH_GEN_CLIENTS", 8);
    let decode_cells: [(usize, usize); 3] = [(8, 8), (8, 32), (24, 8)];
    let mut decode_rows = Vec::new();
    for (plen, ntok) in decode_cells {
        let r = bench_decode(plen, ntok, gen_clients, gen_reqs, cost_us)?;
        eprintln!(
            "[bench_serve] decode prefill={} new={}: {:.1} tok/s, step p95 {:.2} ms",
            r.prefill_len, r.new_tokens, r.tokens_per_s, r.step_p95_ms
        );
        println!(
            "bench_serve JSON: {}",
            Json::obj(vec![
                ("section", Json::Str("decode".into())),
                ("policy", Json::Str("continuous".into())),
                ("prefill_len", Json::Num(r.prefill_len as f64)),
                ("new_tokens", Json::Num(r.new_tokens as f64)),
                ("clients", Json::Num(gen_clients as f64)),
                ("sessions", Json::Num(r.ok as f64)),
                ("errors", Json::Num(r.errors as f64)),
                ("tokens_per_s", Json::Num(r.tokens_per_s)),
                ("step_p95_ms", Json::Num(r.step_p95_ms)),
                ("prefill_p95_ms", Json::Num(r.prefill_p95_ms)),
            ])
        );
        decode_rows.push(r);
    }
    let dtable: Vec<Vec<String>> = decode_rows
        .iter()
        .map(|r| {
            vec![
                r.prefill_len.to_string(),
                r.new_tokens.to_string(),
                r.ok.to_string(),
                format!("{:.1}", r.tokens_per_s),
                format!("{:.2}", r.step_p95_ms),
                format!("{:.2}", r.prefill_p95_ms),
            ]
        })
        .collect();
    println!(
        "\n## decode — slot-pinned generation sessions ({gen_clients} closed-loop clients, \
         mock engine)\n\n{}",
        render(
            &["prefill", "new toks", "sessions", "tok/s", "step p95 ms", "prefill p95 ms"],
            &dtable
        )
    );
    anyhow::ensure!(
        decode_rows.iter().all(|r| r.tokens_per_s > 0.0),
        "decode matrix produced no tokens"
    );

    // -- decode scaling: batched multi-session step vs GEMV loop -------------
    let scale_reqs = env_usize("QTX_BENCH_SCALE_REQS", 4);
    let mut scale_rows: Vec<(ScaleRow, ScaleRow)> = Vec::new();
    for sessions in [1usize, 4, 8, 16] {
        let gemv = bench_decode_scale(sessions, true, scale_reqs, cost_us)?;
        let batched = bench_decode_scale(sessions, false, scale_reqs, cost_us)?;
        eprintln!(
            "[bench_serve] decode_scaling sessions={}: batched {:.1} tok/s vs gemv {:.1} tok/s \
             ({:.2}x)",
            sessions,
            batched.tokens_per_s,
            gemv.tokens_per_s,
            batched.tokens_per_s / gemv.tokens_per_s
        );
        for r in [&gemv, &batched] {
            println!(
                "bench_serve JSON: {}",
                Json::obj(vec![
                    ("section", Json::Str("decode_scaling".into())),
                    ("policy", Json::Str("continuous".into())),
                    ("mode", Json::Str(r.mode.into())),
                    ("sessions", Json::Num(r.sessions as f64)),
                    ("prefill_len", Json::Num(8.0)),
                    ("new_tokens", Json::Num(24.0)),
                    ("tokens_per_s", Json::Num(r.tokens_per_s)),
                    ("inter_token_p95_ms", Json::Num(r.inter_token_p95_ms)),
                ])
            );
        }
        scale_rows.push((gemv, batched));
    }
    let stable: Vec<Vec<String>> = scale_rows
        .iter()
        .map(|(g, b)| {
            vec![
                b.sessions.to_string(),
                format!("{:.1}", b.tokens_per_s),
                format!("{:.1}", g.tokens_per_s),
                format!("{:.2}x", b.tokens_per_s / g.tokens_per_s),
                format!("{:.2}", b.inter_token_p95_ms),
                format!("{:.2}", g.inter_token_p95_ms),
            ]
        })
        .collect();
    println!(
        "\n## decode scaling — batched multi-session step vs per-session GEMV loop \
         (mock engine, step cost charged once per batched pass)\n\n{}",
        render(
            &[
                "sessions",
                "batched tok/s",
                "gemv tok/s",
                "speedup",
                "batched it p95 ms",
                "gemv it p95 ms",
            ],
            &stable
        )
    );
    let (g16, b16) = scale_rows.last().unwrap();
    anyhow::ensure!(
        b16.tokens_per_s > g16.tokens_per_s,
        "batched decode at {} sessions ({:.1} tok/s) did not beat the GEMV loop ({:.1} tok/s)",
        b16.sessions,
        b16.tokens_per_s,
        g16.tokens_per_s
    );

    // -- observability overhead: tracing on vs off ---------------------------
    let obs_rows = [
        bench_obs("off", 0, clients, reqs, cost_us)?,
        bench_obs("on", 256, clients, reqs, cost_us)?,
    ];
    let obs_base = &obs_rows[0];
    for r in &obs_rows {
        eprintln!(
            "[bench_serve] obs tracing={}: {:.1} req/s, {:.1} decode tok/s",
            r.mode, r.rps, r.tokens_per_s
        );
        println!(
            "bench_serve JSON: {}",
            Json::obj(vec![
                ("section", Json::Str("obs_overhead".into())),
                ("tracing", Json::Str(r.mode.into())),
                ("clients", Json::Num(clients as f64)),
                ("throughput_rps", Json::Num(r.rps)),
                ("decode_tokens_per_s", Json::Num(r.tokens_per_s)),
                ("rps_vs_off", Json::Num(r.rps / obs_base.rps)),
                ("tokens_vs_off", Json::Num(r.tokens_per_s / obs_base.tokens_per_s)),
            ])
        );
    }
    let otable: Vec<Vec<String>> = obs_rows
        .iter()
        .map(|r| {
            vec![
                r.mode.to_string(),
                format!("{:.1}", r.rps),
                format!("{:.1}", r.tokens_per_s),
                format!("{:.3}x", r.rps / obs_base.rps),
                format!("{:.3}x", r.tokens_per_s / obs_base.tokens_per_s),
            ]
        })
        .collect();
    println!(
        "\n## observability overhead — request tracing on vs off (mock engine, \
         continuous batching)\n\n{}",
        render(&["tracing", "req/s", "decode tok/s", "req/s vs off", "tok/s vs off"], &otable)
    );

    // -- latency vs open connections (event-loop front-end) ------------------
    raise_nofile_limit(4096);
    let mut conn_rows = Vec::new();
    for held in [16usize, 256, 1024] {
        let r = bench_connections(held, clients, reqs, cost_us)?;
        eprintln!(
            "[bench_serve] connections held={}: {:.1} req/s, p95 {:.2} ms ({} io thread)",
            r.held, r.rps, r.p95, r.io_threads
        );
        println!(
            "bench_serve JSON: {}",
            Json::obj(vec![
                ("section", Json::Str("open_connections".into())),
                ("policy", Json::Str("continuous".into())),
                ("held_connections", Json::Num(r.held as f64)),
                ("clients", Json::Num(clients as f64)),
                ("throughput_rps", Json::Num(r.rps)),
                ("p50_ms", Json::Num(r.p50)),
                ("p95_ms", Json::Num(r.p95)),
                ("io_threads", Json::Num(r.io_threads as f64)),
            ])
        );
        conn_rows.push(r);
    }
    let ctable: Vec<Vec<String>> = conn_rows
        .iter()
        .map(|r| {
            vec![
                r.held.to_string(),
                format!("{:.1}", r.rps),
                format!("{:.2}", r.p50),
                format!("{:.2}", r.p95),
                r.io_threads.to_string(),
            ]
        })
        .collect();
    println!(
        "\n## latency vs open connections — {clients} closed-loop clients while the \
         event-loop front-end holds idle keep-alive sockets\n\n{}",
        render(&["held conns", "req/s", "p50 ms", "p95 ms", "io threads"], &ctable)
    );

    // -- routing: fleet scale + fault recovery -------------------------------
    let route_reqs = env_usize("QTX_BENCH_ROUTE_REQS", 16);
    let route_clients = 4usize;
    let mut route_rows = Vec::new();
    for replicas in [1usize, 2, 4] {
        let r = bench_route_scale(replicas, route_clients, route_reqs, cost_us)?;
        eprintln!(
            "[bench_serve] routing replicas={}: {:.1} req/s, p95 {:.2} ms, {:.1} tok/s \
             ({} shed)",
            r.replicas, r.rps, r.p95, r.tok_s, r.shed
        );
        println!(
            "bench_serve JSON: {}",
            Json::obj(vec![
                ("section", Json::Str("routing".into())),
                ("row", Json::Str("scale".into())),
                ("replicas", Json::Num(r.replicas as f64)),
                ("clients", Json::Num(route_clients as f64)),
                ("requests", Json::Num((route_clients * route_reqs) as f64)),
                ("throughput_rps", Json::Num(r.rps)),
                ("p95_ms", Json::Num(r.p95)),
                ("decode_tokens_per_s", Json::Num(r.tok_s)),
                ("shed", Json::Num(r.shed as f64)),
            ])
        );
        route_rows.push(r);
    }
    let rbase = route_rows[0].rps;
    let rtable: Vec<Vec<String>> = route_rows
        .iter()
        .map(|r| {
            vec![
                r.replicas.to_string(),
                format!("{:.1}", r.rps),
                format!("{:.2}", r.p95),
                format!("{:.1}", r.tok_s),
                r.shed.to_string(),
                format!("{:.2}x", r.rps / rbase),
            ]
        })
        .collect();
    println!(
        "\n## routing — `qtx route` fronting N serve replicas ({route_clients} closed-loop \
         clients, mock engine)\n\n{}",
        render(&["replicas", "req/s", "p95 ms", "decode tok/s", "shed", "vs 1"], &rtable)
    );

    let rec = bench_route_recovery(route_clients, route_reqs, cost_us)?;
    eprintln!(
        "[bench_serve] routing recovery: {} reqs over a mid-run kill ({:.0} retries), \
         detect {:.0} ms, rejoin {:.0} ms",
        rec.requests, rec.retries, rec.detect_ms, rec.rejoin_ms
    );
    println!(
        "bench_serve JSON: {}",
        Json::obj(vec![
            ("section", Json::Str("routing".into())),
            ("row", Json::Str("recovery".into())),
            ("replicas", Json::Num(2.0)),
            ("clients", Json::Num(route_clients as f64)),
            ("requests", Json::Num(rec.requests as f64)),
            ("retries", Json::Num(rec.retries)),
            ("detect_ms", Json::Num(rec.detect_ms)),
            ("rejoin_ms", Json::Num(rec.rejoin_ms)),
        ])
    );
    println!(
        "\nrecovery drill (kill-after:8 on one of two replicas): detect {:.0} ms, \
         half-open rejoin {:.0} ms, {:.0} score retries, zero lost requests.",
        rec.detect_ms, rec.rejoin_ms, rec.retries
    );

    // -- hot reload: /admin/reload under closed-loop load --------------------
    let mut reload_rows = Vec::new();
    for routed in [false, true] {
        let r = bench_reload(routed, route_clients, route_reqs, cost_us)?;
        eprintln!(
            "[bench_serve] hot_reload {}: {} reqs across {} reloads, admin {:.1} ms, \
             generation {:.0}, zero lost",
            r.mode, r.requests, r.reloads, r.reload_ms, r.generation
        );
        println!(
            "bench_serve JSON: {}",
            Json::obj(vec![
                ("section", Json::Str("hot_reload".into())),
                ("mode", Json::Str(r.mode.into())),
                ("clients", Json::Num(route_clients as f64)),
                ("requests", Json::Num(r.requests as f64)),
                ("reloads", Json::Num(r.reloads as f64)),
                ("throughput_rps", Json::Num(r.rps)),
                ("p95_ms", Json::Num(r.p95)),
                ("reload_ms", Json::Num(r.reload_ms)),
                ("final_generation", Json::Num(r.generation)),
            ])
        );
        reload_rows.push(r);
    }
    let reload_table: Vec<Vec<String>> = reload_rows
        .iter()
        .map(|r| {
            vec![
                r.mode.to_string(),
                r.requests.to_string(),
                r.reloads.to_string(),
                format!("{:.1}", r.rps),
                format!("{:.2}", r.p95),
                format!("{:.1}", r.reload_ms),
                format!("{:.0}", r.generation),
            ]
        })
        .collect();
    println!(
        "\n## hot reload — `POST /admin/reload` under closed-loop load \
         ({route_clients} clients, mock engine; zero lost requests enforced)\n\n{}",
        render(
            &["mode", "reqs", "reloads", "req/s", "p95 ms", "admin ms", "final gen"],
            &reload_table
        )
    );

    // -- engine dimension: pjrt vs native-int8 -------------------------------
    let iters = env_usize("QTX_BENCH_ENGINE_ITERS", 10);
    if let Some(engine_rows) = engine_compare(iters)? {
        let pjrt_rps = engine_rows[0].rows_per_s;
        for r in &engine_rows {
            let speedup = r.rows_per_s / pjrt_rps;
            eprintln!(
                "[bench_serve] engine {}: {:.2} ms/dispatch, {:.1} rows/s ({:.2}x vs pjrt)",
                r.engine, r.dispatch_ms, r.rows_per_s, speedup
            );
            println!(
                "bench_serve JSON: {}",
                Json::obj(vec![
                    ("section", Json::Str("engine_compare".into())),
                    ("engine", Json::Str(r.engine.into())),
                    ("config", Json::Str(r.config.clone())),
                    ("max_batch", Json::Num(r.max_batch as f64)),
                    ("seq_len", Json::Num(r.seq_len as f64)),
                    ("iters", Json::Num(iters as f64)),
                    ("dispatch_ms", Json::Num(r.dispatch_ms)),
                    ("rows_per_s", Json::Num(r.rows_per_s)),
                    ("speedup_vs_pjrt", Json::Num(speedup)),
                ])
            );
        }
        let etable: Vec<Vec<String>> = engine_rows
            .iter()
            .map(|r| {
                vec![
                    r.engine.to_string(),
                    r.max_batch.to_string(),
                    format!("{:.2}", r.dispatch_ms),
                    format!("{:.1}", r.rows_per_s),
                    format!("{:.2}x", r.rows_per_s / pjrt_rps),
                ]
            })
            .collect();
        println!(
            "\n## engine dimension — fake-quant pjrt vs native-int8 ({}, \
             full-batch dispatches)\n\n{}",
            engine_rows[0].config,
            render(&["engine", "batch", "ms/dispatch", "rows/s", "vs pjrt"], &etable)
        );
    }
    Ok(())
}
