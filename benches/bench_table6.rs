//! Bench target regenerating the paper's Table 6 at miniature scale.
//!
//! This drives exactly the same code path as `qtx table6`; the full-scale
//! regeneration is `./target/release/qtx table6 --steps 800`. Bench defaults
//! keep `cargo bench` tractable on this single-core testbed (override via
//! QTX_BENCH_STEPS / QTX_BENCH_SEEDS).

fn main() -> anyhow::Result<()> {
    for (k, v) in [("QTX_EVAL_BATCHES", "2"), ("QTX_METRIC_BATCHES", "2"), ("QTX_CALIB_BATCHES", "2")] {
        if std::env::var(k).is_err() {
            std::env::set_var(k, v);
        }
    }
    let steps = std::env::var("QTX_BENCH_STEPS").unwrap_or_else(|_| "12".into());
    let seeds = std::env::var("QTX_BENCH_SEEDS").unwrap_or_else(|_| "0".into());
    let argv = vec![
        "table6".to_string(),
        "--steps".to_string(), steps,
        "--seeds".to_string(), seeds,
        "--out".to_string(), "none".to_string(),
    ];
    let args = qtx::util::cli::Args::parse(&argv)?;
    qtx::cli::tables::run("table6", &args)
}
