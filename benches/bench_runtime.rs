//! Table 11 analogue: training-step runtime of vanilla / clipped softmax /
//! gated attention across the three model families.
//!
//! The paper reports wall-clock pre-training hours on A100s (Table 11,
//! Appendix D); here we measure per-step latency of the AOT train_step on
//! the CPU PJRT runtime — same comparison (clipped softmax ≈ vanilla,
//! gating adds a few percent), different absolute scale.
//!
//! Run: cargo bench --bench bench_runtime   (needs `make artifacts`)
//! Env: QTX_BENCH_STEPS (default 12) timed steps after 3 warmup.

use qtx::coordinator::trainer::{train, TrainOptions};
use qtx::data::batch::{make_provider, Stream};
use qtx::metrics::table::render;
use qtx::runtime::artifact::Artifact;
use qtx::runtime::client::Runtime;

fn steps_budget() -> usize {
    std::env::var("QTX_BENCH_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(12)
}

fn time_config(rt: &Runtime, root: &std::path::Path, config: &str, gamma: f32) -> anyhow::Result<(f64, f64)> {
    let art = Artifact::load(root, config)?;
    let cfg = &art.manifest.config;
    let steps = steps_budget();
    // Warmup (includes XLA compile) then timed run.
    let mut provider = make_provider(cfg, 0, Stream::Train);
    let warm = TrainOptions { gamma, log_every: 0, ..TrainOptions::new(0, 3) };
    train(rt, &art, &warm, provider.as_mut())?;
    let opts = TrainOptions { gamma, log_every: 0, ..TrainOptions::new(0, steps) };
    let res = train(rt, &art, &opts, provider.as_mut())?;
    Ok((1000.0 / res.steps_per_sec, res.steps_per_sec))
}

fn main() -> anyhow::Result<()> {
    let (root, _) = qtx::coordinator::experiment::default_paths();
    let rt = Runtime::cpu()?;
    let rows_def: Vec<(&str, &str, f32)> = vec![
        ("BERT  Vanilla", "bert_tiny_softmax", 0.0),
        ("BERT  Clipped softmax", "bert_tiny_softmax", -0.03),
        ("BERT  Gated (Linear)", "bert_tiny_gated_linear", 0.0),
        ("BERT  Gated (MLP)", "bert_tiny_gated_mlp", 0.0),
        ("OPT   Vanilla", "opt_tiny_softmax", 0.0),
        ("OPT   Clipped softmax", "opt_tiny_softmax", -0.1875),
        ("OPT   Gated (Linear)", "opt_tiny_gated_linear", 0.0),
        ("ViT   Vanilla", "vit_tiny_softmax", 0.0),
        ("ViT   Clipped softmax", "vit_tiny_softmax", -0.001),
        ("ViT   Gated (Linear)", "vit_tiny_gated_linear", 0.0),
    ];
    let mut rows = Vec::new();
    let mut baseline_ms = 0.0;
    for (label, config, gamma) in rows_def {
        let (ms, sps) = time_config(&rt, &root, config, gamma)?;
        if label.ends_with("Vanilla") {
            baseline_ms = ms;
        }
        rows.push(vec![
            label.to_string(),
            format!("{ms:.1}"),
            format!("{sps:.2}"),
            format!("{:+.1}%", 100.0 * (ms - baseline_ms) / baseline_ms),
        ]);
        eprintln!("[bench_runtime] {label}: {ms:.1} ms/step");
    }
    println!(
        "\n## Table 11 analogue — train-step runtime (CPU PJRT)\n\n{}",
        render(&["Method", "ms/step", "steps/s", "vs family vanilla"], &rows)
    );
    Ok(())
}
