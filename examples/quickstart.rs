//! Quickstart: the smallest useful tour of the qtx public API.
//!
//! Loads a pre-built artifact, trains a tiny BERT on the synthetic
//! delimiter language for a handful of steps, and evaluates perplexity —
//! all from rust, no python on the path.
//!
//! Run with:  cargo run --release --example quickstart
//! (requires `make artifacts` first)

use qtx::coordinator::evaluator::evaluate;
use qtx::coordinator::trainer::{train, TrainOptions};
use qtx::data::batch::{make_provider, Stream, EVAL_SEED};
use qtx::runtime::artifact::Artifact;
use qtx::runtime::client::Runtime;

fn main() -> anyhow::Result<()> {
    let (artifacts, _) = qtx::coordinator::experiment::default_paths();
    let rt = Runtime::cpu()?;
    let art = Artifact::load(&artifacts, "bert_tiny_softmax")?;
    let cfg = &art.manifest.config;
    println!(
        "loaded {}: {} layers, d_model {}, {} quant points",
        cfg.name,
        cfg.n_layers,
        cfg.d_model,
        art.manifest.quant_points.len()
    );

    // Train for 100 steps on the synthetic corpus (vanilla softmax:
    // gamma=0, zeta=1 — clipped softmax is the same artifact with
    // different runtime inputs).
    let opts = TrainOptions { log_every: 25, ..TrainOptions::new(0, 100) };
    let mut provider = make_provider(cfg, 0, Stream::Train);
    let result = train(&rt, &art, &opts, provider.as_mut())?;
    println!(
        "trained 100 steps: loss {:.3} -> {:.3} ({:.1} steps/s)",
        result.losses[0],
        result.losses.last().unwrap(),
        result.steps_per_sec
    );

    // Evaluate on the shared validation stream.
    let mut eval_provider = make_provider(cfg, EVAL_SEED, Stream::Eval);
    let fp = evaluate(&rt, &art, &result.params, eval_provider.as_mut(), 8, 0.0, 1.0, 1.0)?;
    println!("validation perplexity: {:.2} (uniform would be {})", fp.ppl, cfg.vocab_size);
    Ok(())
}
