//! Outlier & attention analysis example (the paper's §3 investigation,
//! Figs 1-2, as a library-API walkthrough).
//!
//! Trains a vanilla BERT-tiny briefly, then localizes >6σ outliers by
//! hidden dimension / token position / token identity and summarizes which
//! attention heads implement the "no-op" pattern (probability mass dumped
//! on delimiter tokens whose values are small).
//!
//! Run:  cargo run --release --example outlier_analysis [STEPS]

use qtx::analysis::attention::{ascii_heatmap, summarize_heads};
use qtx::analysis::outliers::OutlierCounts;
use qtx::coordinator::calibrator::{collect, CollectOptions};
use qtx::coordinator::trainer::{train, TrainOptions};
use qtx::data::batch::{make_provider, Stream, EVAL_SEED};
use qtx::data::vocab;
use qtx::runtime::artifact::Artifact;
use qtx::runtime::client::Runtime;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(400);
    let (artifacts, _) = qtx::coordinator::experiment::default_paths();
    let rt = Runtime::cpu()?;
    let art = Artifact::load(&artifacts, "bert_tiny_softmax")?;
    let cfg = art.manifest.config.clone();

    let opts = TrainOptions { log_every: 0, ..TrainOptions::new(0, steps) };
    let mut provider = make_provider(&cfg, 0, Stream::Train);
    let result = train(&rt, &art, &opts, provider.as_mut())?;
    println!("trained {} steps (final loss {:.3})", steps, result.losses.last().unwrap());

    let last = cfg.n_layers - 1;
    let mut counts = OutlierCounts::default();
    let copts = CollectOptions { gamma: 0.0, zeta: 1.0, gate_scale: 1.0 };
    let mut eval_p = make_provider(&cfg, EVAL_SEED, Stream::Eval);
    let mut shown = false;
    collect(&rt, &art, &result.params, eval_p.as_mut(), 4, &copts, |ab| {
        let t = ab.get(&format!("L{last}.block_out")).unwrap();
        counts.observe(t, ab.tokens.as_deref());
        if !shown {
            shown = true;
            let probs = ab.get(&format!("L{last}.probs")).unwrap();
            let values = ab.get(&format!("L{last}.values")).unwrap();
            let s = summarize_heads(probs, values, None, ab.tokens.as_deref(), None);
            println!("\nhead summaries (layer {last}):");
            for h in &s {
                println!(
                    "  head {}: delimiter mass {:.2}, |v| at delimiters {:.3} vs mean {:.3}, update |p·v| {:.3}",
                    h.head, h.delim_mass, h.delim_value_norm, h.mean_value_norm, h.update_norm
                );
            }
            let noop = s.iter().max_by(|a, b| a.delim_mass.total_cmp(&b.delim_mass)).unwrap();
            println!("\nmost delimiter-focused head ({}):", noop.head);
            println!("{}", ascii_heatmap(probs, 0, noop.head, 16));
        }
        Ok(())
    })?;

    println!("outliers (>6σ) in layer {last} block output: {}", counts.total);
    println!("  by hidden dim: {:?}", counts.top_dims(6));
    println!(
        "  fraction at delimiter tokens: {:.1}%",
        100.0 * counts.token_fraction(&vocab::DELIMITERS)
    );
    Ok(())
}
