//! End-to-end driver (the repository's headline validation run):
//!
//! 1. trains a tiny BERT transformer on the synthetic delimiter-language
//!    corpus for several hundred steps, logging the loss curve;
//! 2. evaluates floating-point perplexity on the held-out stream;
//! 3. measures the paper's outlier metrics (max ‖x‖∞, avg kurtosis);
//! 4. runs the full W8A8 PTQ pipeline (symmetric min-max weights,
//!    99.999-percentile activations, 16 calibration batches);
//! 5. reports FP vs quantized perplexity — the paper's headline comparison
//!    — for both vanilla softmax and clipped softmax (γ=-0.03), proving
//!    every layer of the stack composes.
//!
//! Run:  cargo run --release --example train_and_quantize [STEPS]
//! The results of the recorded run live in EXPERIMENTS.md §End-to-end.

use qtx::coordinator::calibrator::{outlier_metrics, CollectOptions};
use qtx::coordinator::evaluator::evaluate;
use qtx::coordinator::quantize::{quantized_eval, QuantSpec};
use qtx::coordinator::trainer::{train, TrainOptions};
use qtx::data::batch::{make_provider, Stream, EVAL_SEED};
use qtx::runtime::artifact::Artifact;
use qtx::runtime::client::Runtime;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(600);
    let (artifacts, _) = qtx::coordinator::experiment::default_paths();
    let rt = Runtime::cpu()?;
    let art = Artifact::load(&artifacts, "bert_tiny_softmax")?;
    let cfg = art.manifest.config.clone();

    println!("=== end-to-end: train -> eval -> PTQ (W8A8) on {} ===", cfg.name);
    for (label, gamma) in [("vanilla softmax", 0.0f32), ("clipped softmax γ=-0.03", -0.03)] {
        let opts = TrainOptions {
            gamma,
            log_every: steps / 10,
            ..TrainOptions::new(0, steps)
        };
        let mut provider = make_provider(&cfg, 0, Stream::Train);
        let t0 = std::time::Instant::now();
        let result = train(&rt, &art, &opts, provider.as_mut())?;
        println!(
            "\n[{label}] {} steps in {:.0}s ({:.1} steps/s)",
            steps,
            t0.elapsed().as_secs_f64(),
            result.steps_per_sec
        );
        // Loss curve, decimated.
        let pts: Vec<String> = result
            .losses
            .iter()
            .step_by((steps / 8).max(1))
            .map(|l| format!("{l:.3}"))
            .collect();
        println!("[{label}] loss curve: {}", pts.join(" -> "));

        let mut eval_p = make_provider(&cfg, EVAL_SEED, Stream::Eval);
        let fp = evaluate(&rt, &art, &result.params, eval_p.as_mut(), 16, gamma, 1.0, 1.0)?;
        let om = outlier_metrics(
            &rt,
            &art,
            &result.params,
            eval_p.as_mut(),
            8,
            &CollectOptions { gamma, zeta: 1.0, gate_scale: 1.0 },
        )?;
        let q = quantized_eval(
            &rt,
            &art,
            &result.params,
            &QuantSpec::w8a8(),
            gamma,
            1.0,
            1.0,
            16,
            1,
        )?;
        println!(
            "[{label}] FP ppl {:.3} | max inf-norm {:.1} | avg kurtosis {:.1} | W8A8 ppl {:.3}",
            fp.ppl,
            om.max_inf_norm(),
            om.avg_kurtosis(),
            q.result.ppl
        );
    }
    println!("\nExpected shape (paper Table 1/2): the clipped-softmax run has a");
    println!("much smaller inf-norm/kurtosis and a W8A8 ppl close to its FP ppl,");
    println!("while the vanilla run degrades more under quantization.");
    Ok(())
}
