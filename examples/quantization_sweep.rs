//! Bitwidth / range-estimator sweep on one trained model (the Table 10 /
//! §C.4 design space as a library-API example).
//!
//! Trains once, then evaluates every (weight bits, activation bits, weight
//! estimator, activation estimator) combination — all from the same AOT
//! artifact, because qmax and the activation scales are runtime inputs.
//!
//! Run:  cargo run --release --example quantization_sweep [STEPS]

use qtx::coordinator::evaluator::evaluate;
use qtx::coordinator::quantize::{quantized_eval, QuantSpec};
use qtx::coordinator::trainer::{train, TrainOptions};
use qtx::data::batch::{make_provider, Stream, EVAL_SEED};
use qtx::quant::estimators::EstimatorKind;
use qtx::runtime::artifact::Artifact;
use qtx::runtime::client::Runtime;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(400);
    let (artifacts, _) = qtx::coordinator::experiment::default_paths();
    let rt = Runtime::cpu()?;
    let art = Artifact::load(&artifacts, "bert_tiny_softmax")?;
    let cfg = art.manifest.config.clone();

    // Clipped softmax so the quantized numbers are meaningful at low bits.
    let gamma = -0.03f32;
    let opts = TrainOptions { gamma, log_every: 0, ..TrainOptions::new(0, steps) };
    let mut provider = make_provider(&cfg, 0, Stream::Train);
    let result = train(&rt, &art, &opts, provider.as_mut())?;
    let mut eval_p = make_provider(&cfg, EVAL_SEED, Stream::Eval);
    let fp = evaluate(&rt, &art, &result.params, eval_p.as_mut(), 16, gamma, 1.0, 1.0)?;
    println!("FP perplexity: {:.3}\n", fp.ppl);
    println!("{:<10} {:<10} {:<12} {:>10}", "weights", "acts", "estimators", "ppl");

    let acts: [(u32, EstimatorKind); 4] = [
        (8, EstimatorKind::Percentile { pct: 99.999 }),
        (8, EstimatorKind::MinMax),
        (8, EstimatorKind::RunningMinMax { momentum: 0.9 }),
        (6, EstimatorKind::Mse),
    ];
    for w_bits in [8u32, 6, 4] {
        for w_est in [EstimatorKind::MinMax, EstimatorKind::Mse] {
            for (a_bits, a_est) in acts {
                let spec = QuantSpec { w_bits, a_bits, w_est, a_est, calib_batches: 8 };
                let out = quantized_eval(
                    &rt, &art, &result.params, &spec, gamma, 1.0, 1.0, 8, 1,
                )?;
                println!(
                    "W{:<9} A{:<9} {:<12} {:>10.3}",
                    w_bits,
                    a_bits,
                    format!("{}/{}", w_est.name(), a_est.name()),
                    out.result.ppl
                );
            }
        }
    }
    Ok(())
}
