"""L1 Pallas kernel: fused multi-head attention with clipped softmax + gating.

This is the paper's compute hot-spot. One fused kernel computes

    out = sigmoid(gate) * clip((zeta-gamma)*softmax(q k^T / sqrt(d)) + gamma, 0, 1) @ v

covering all three attention variants studied in the paper:

  * vanilla softmax      — gamma=0, zeta=1, no gate      (eq. 3)
  * clipped softmax      — gamma<0 (and/or zeta>1)       (eq. 4)
  * gated attention      — gate logits from G(x)         (eq. 5)

gamma/zeta are *runtime scalars* (not trace-time constants), so a single AOT
artifact serves the whole hyperparameter sweep of Tables 1/5/8 and Fig. 6.

Both the forward and the backward pass are Pallas kernels, tied together with
``jax.custom_vjp`` (pallas_call has no automatic differentiation rule). The
backward recomputes the probability matrix flash-attention style instead of
saving it, so the residuals are just (q, k, v, gate): per-tile VMEM stays at
O(T*d_head + T^2) and no (B,H,T,T) tensor hits HBM between passes.

TPU mapping (see DESIGN.md "Hardware adaptation"): the natural TPU grid is
(B, H) with T-tiling in BlockSpec; both matmuls (T x d_head x T) land on the
MXU and the stretch-clip epilogue is fused VPU elementwise work. Because this
environment executes the kernel through ``interpret=True`` on a CPU PJRT
plugin (real-TPU lowering emits a Mosaic custom-call the CPU cannot run), we
keep the whole tensor in one block: measured on this testbed, the single-block
mapping is ~25% faster than grid-over-heads (the XLA while-loop emitted per
grid step dominates at these tile sizes) — see EXPERIMENTS.md §Perf. The
TPU grid would reintroduce (B, H) tiling via BlockSpec index maps.

Clipping semantics match the paper exactly: values clipped to 0 or 1 get a
*zero* gradient ("whenever values are clipped they will not give a gradient",
Section 4.1), which is what stops the outlier growth during training.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _stable_softmax(scores: jax.Array) -> jax.Array:
    """Numerically stable softmax over the last axis (in-kernel)."""
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def _scores(q: jax.Array, k: jax.Array, causal: bool) -> jax.Array:
    """(B, H, T, D) x (B, H, S, D) -> (B, H, T, S) scaled scores."""
    d_head = q.shape[-1]
    s = jnp.einsum("bhtd,bhsd->bhts", q, k, preferred_element_type=jnp.float32)
    s = s * (1.0 / math.sqrt(d_head))
    if causal:
        t = s.shape[-1]
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        s = jnp.where(mask[None, None], s, _NEG_INF)
    return s


def _fwd_kernel(q_ref, k_ref, v_ref, g_ref, gam_ref, zet_ref, o_ref, *, causal, use_gate):
    """Forward tile: all heads, full batch in-block (see module docs)."""
    q = q_ref[...]  # (B, H, T, D)
    k = k_ref[...]
    v = v_ref[...]
    gamma = gam_ref[0]
    zeta = zet_ref[0]
    p0 = _stable_softmax(_scores(q, k, causal))
    p = jnp.clip((zeta - gamma) * p0 + gamma, 0.0, 1.0)
    out = jnp.einsum("bhts,bhsd->bhtd", p, v, preferred_element_type=jnp.float32)
    if use_gate:
        out = jax.nn.sigmoid(g_ref[...]) * out
    o_ref[...] = out.astype(o_ref.dtype)


def _probs_kernel(q_ref, k_ref, gam_ref, zet_ref, p_ref, *, causal):
    """Probability-matrix tile (used by act_collect / analysis programs)."""
    q = q_ref[...]
    k = k_ref[...]
    gamma = gam_ref[0]
    zeta = zet_ref[0]
    p0 = _stable_softmax(_scores(q, k, causal))
    p_ref[...] = jnp.clip((zeta - gamma) * p0 + gamma, 0.0, 1.0).astype(p_ref.dtype)


def _bwd_kernel(
    q_ref, k_ref, v_ref, g_ref, gam_ref, zet_ref, do_ref,
    dq_ref, dk_ref, dv_ref, dg_ref, *, causal, use_gate,
):
    """Backward tile: recompute probs, propagate through clip -> softmax.

    The clip derivative is an interior mask: positions where the stretched
    probability left (0, 1) contribute zero gradient — the mechanism that
    stops outlier growth (Section 4.1).
    """
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    gamma = gam_ref[0]
    zeta = zet_ref[0]
    do = do_ref[...]

    p0 = _stable_softmax(_scores(q, k, causal))
    y = (zeta - gamma) * p0 + gamma
    p = jnp.clip(y, 0.0, 1.0)

    if use_gate:
        glog = g_ref[...]  # (B, H, T, 1)
        sig = jax.nn.sigmoid(glog)
        pv = jnp.einsum("bhts,bhsd->bhtd", p, v, preferred_element_type=jnp.float32)
        dgate = jnp.sum(do * pv, axis=-1, keepdims=True) * sig * (1.0 - sig)
        dpv = sig * do
        dg_ref[...] = dgate.astype(dg_ref.dtype)
    else:
        dpv = do
        dg_ref[...] = jnp.zeros_like(g_ref[...])

    dp = jnp.einsum("bhtd,bhsd->bhts", dpv, v, preferred_element_type=jnp.float32)
    dv = jnp.einsum("bhts,bhtd->bhsd", p, dpv, preferred_element_type=jnp.float32)

    interior = jnp.logical_and(y > 0.0, y < 1.0).astype(jnp.float32)
    dp0 = dp * (zeta - gamma) * interior
    # softmax VJP (rowwise): ds = p0 * (dp0 - <dp0, p0>)
    ds = p0 * (dp0 - jnp.sum(dp0 * p0, axis=-1, keepdims=True))
    inv_sqrt_d = 1.0 / math.sqrt(q.shape[-1])
    dq = jnp.einsum("bhts,bhsd->bhtd", ds, k, preferred_element_type=jnp.float32) * inv_sqrt_d
    dk = jnp.einsum("bhts,bhtd->bhsd", ds, q, preferred_element_type=jnp.float32) * inv_sqrt_d

    dq_ref[...] = dq.astype(dq_ref.dtype)
    dk_ref[...] = dk.astype(dk_ref.dtype)
    dv_ref[...] = dv.astype(dv_ref.dtype)


def _head_spec(b, h, t, d):
    """Single whole-tensor block (B, H, T, D) — see module docs for the
    measured grid-over-heads comparison and the TPU mapping."""
    return pl.BlockSpec((b, h, t, d), lambda: (0, 0, 0, 0))


def _scalar_spec():
    return pl.BlockSpec((1,), lambda: (0,))


def _as_scalar_array(x) -> jax.Array:
    return jnp.reshape(jnp.asarray(x, dtype=jnp.float32), (1,))


def _fwd_call(q, k, v, g, gamma, zeta, *, causal, use_gate):
    b, h, t, d = q.shape
    return pl.pallas_call(
        functools.partial(_fwd_kernel, causal=causal, use_gate=use_gate),
        in_specs=[
            _head_spec(b, h, t, d),
            _head_spec(b, h, t, d),
            _head_spec(b, h, t, d),
            _head_spec(b, h, t, 1),
            _scalar_spec(),
            _scalar_spec(),
        ],
        out_specs=_head_spec(b, h, t, d),
        out_shape=jax.ShapeDtypeStruct((b, h, t, d), q.dtype),
        interpret=True,
    )(q, k, v, g, gamma, zeta)


def _bwd_call(q, k, v, g, gamma, zeta, do, *, causal, use_gate):
    b, h, t, d = q.shape
    return pl.pallas_call(
        functools.partial(_bwd_kernel, causal=causal, use_gate=use_gate),
        in_specs=[
            _head_spec(b, h, t, d),
            _head_spec(b, h, t, d),
            _head_spec(b, h, t, d),
            _head_spec(b, h, t, 1),
            _scalar_spec(),
            _scalar_spec(),
            _head_spec(b, h, t, d),
        ],
        out_specs=(
            _head_spec(b, h, t, d),
            _head_spec(b, h, t, d),
            _head_spec(b, h, t, d),
            _head_spec(b, h, t, 1),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, h, t, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, t, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, t, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, t, 1), q.dtype),
        ),
        interpret=True,
    )(q, k, v, g, gamma, zeta, do)


@functools.lru_cache(maxsize=None)
def _make_attention_op(causal: bool, use_gate: bool):
    """Build the custom-VJP attention op for a (causal, use_gate) variant."""

    @jax.custom_vjp
    def op(q, k, v, g, gamma, zeta):
        return _fwd_call(q, k, v, g, gamma, zeta, causal=causal, use_gate=use_gate)

    def fwd(q, k, v, g, gamma, zeta):
        out = _fwd_call(q, k, v, g, gamma, zeta, causal=causal, use_gate=use_gate)
        return out, (q, k, v, g, gamma, zeta)

    def bwd(res, do):
        q, k, v, g, gamma, zeta = res
        dq, dk, dv, dg = _bwd_call(
            q, k, v, g, gamma, zeta, do, causal=causal, use_gate=use_gate
        )
        # gamma/zeta are hyperparameter inputs, never trained: zero cotangent.
        return dq, dk, dv, dg, jnp.zeros_like(gamma), jnp.zeros_like(zeta)

    op.defvjp(fwd, bwd)
    return op


def attention(q, k, v, gamma, zeta, gate_logits=None, *, causal: bool = False):
    """Fused attention, the public L1 entry point.

    Args:
      q, k, v: (B, H, T, Dh) projections.
      gamma, zeta: clipped-softmax stretch factors (runtime scalars; 0/1 for
        vanilla softmax).
      gate_logits: optional (B, H, T, 1) gating logits G(x) — eq. (5).
      causal: apply a causal mask (decoder / OPT family).

    Returns: (B, H, T, Dh) attention output, differentiable w.r.t. q, k, v
    and gate_logits.
    """
    use_gate = gate_logits is not None
    if gate_logits is None:
        b, h, t, _ = q.shape
        gate_logits = jnp.zeros((b, h, t, 1), dtype=q.dtype)
    op = _make_attention_op(bool(causal), use_gate)
    return op(q, k, v, gate_logits, _as_scalar_array(gamma), _as_scalar_array(zeta))


def attention_probs(q, k, gamma, zeta, *, causal: bool = False):
    """The clipped-softmax probability matrix (B, H, T, T); forward-only,
    used by the act_collect / analysis programs for Figs 1-3, 8."""
    b, h, t, d = q.shape
    return pl.pallas_call(
        functools.partial(_probs_kernel, causal=bool(causal)),
        in_specs=[
            _head_spec(b, h, t, d),
            _head_spec(b, h, t, d),
            _scalar_spec(),
            _scalar_spec(),
        ],
        out_specs=pl.BlockSpec((b, h, t, t), lambda: (0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, t, t), q.dtype),
        interpret=True,
    )(q, k, _as_scalar_array(gamma), _as_scalar_array(zeta))
