"""L1 Pallas kernel: uniform affine fake quantization (eq. 1).

Used by the ``eval_quant`` program to simulate INT-b activation quantization
in-graph. ``scale``/``zero_point``/``qmax`` are runtime scalars: the rust
calibrator estimates ranges on the host and feeds them in, and ``qmax``
(= 2^b - 1) selects the bitwidth, so the same lowered artifact serves the
whole W*A{4,6,8} sweep of Table 10.

Forward-only (PTQ simulation never backpropagates); a straight-through
estimator VJP is still provided so the op composes if a QAT-style program is
ever traced through it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fq_kernel(x_ref, s_ref, z_ref, qmax_ref, o_ref):
    x = x_ref[...]
    s = s_ref[0]
    z = z_ref[0]
    qmax = qmax_ref[0]
    q = jnp.clip(jnp.round(x / s) + z, 0.0, qmax)
    o_ref[...] = (s * (q - z)).astype(o_ref.dtype)


def _fq_call(x2d, s, z, qmax):
    n, m = x2d.shape
    full = lambda i: (0, 0)
    return pl.pallas_call(
        _fq_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n, m), full),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n, m), full),
        out_shape=jax.ShapeDtypeStruct((n, m), x2d.dtype),
        interpret=True,
    )(x2d, s, z, qmax)


@jax.custom_vjp
def _fq_op(x2d, s, z, qmax):
    return _fq_call(x2d, s, z, qmax)


def _fq_fwd(x2d, s, z, qmax):
    return _fq_call(x2d, s, z, qmax), None


def _fq_bwd(_, g):
    # Straight-through: pass the gradient to x, none to the quant params.
    return g, jnp.zeros((1,), g.dtype), jnp.zeros((1,), g.dtype), jnp.zeros((1,), g.dtype)


_fq_op.defvjp(_fq_fwd, _fq_bwd)


def _as_scalar_array(v) -> jax.Array:
    return jnp.reshape(jnp.asarray(v, dtype=jnp.float32), (1,))


def fake_quant(x: jax.Array, scale, zero_point, qmax) -> jax.Array:
    """Fake-quantize ``x`` (any rank) with per-tensor affine parameters."""
    shape = x.shape
    x2d = jnp.reshape(x, (1, -1)) if x.ndim < 2 else jnp.reshape(x, (-1, shape[-1]))
    out = _fq_op(
        x2d,
        _as_scalar_array(scale),
        _as_scalar_array(zero_point),
        _as_scalar_array(qmax),
    )
    return jnp.reshape(out, shape)
