"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has a reference implementation here,
written with plain ``jax.numpy`` ops only. ``python/tests`` asserts
``assert_allclose(kernel(...), ref(...))`` across shape/dtype/hyperparameter
sweeps (hypothesis), which is the build-time correctness gate for the whole
stack: the L2 model calls the kernels, and the AOT HLO the rust runtime
executes is lowered from exactly these traced ops.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def clipped_softmax(x: jax.Array, gamma, zeta, axis: int = -1) -> jax.Array:
    """Eq. (4) of the paper: ``clip((zeta - gamma)*softmax(x) + gamma, 0, 1)``.

    gamma <= 0 stretches the lower end so exact zeros are representable with a
    finite softmax-input range; zeta >= 1 does the same for exact ones.
    gamma=0, zeta=1 recovers the vanilla softmax exactly.
    """
    p = jax.nn.softmax(x, axis=axis)
    return jnp.clip((zeta - gamma) * p + gamma, 0.0, 1.0)


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    gate_logits,
    gamma,
    zeta,
    *,
    causal: bool = False,
) -> jax.Array:
    """Reference (multi-head) attention with clipped softmax and optional
    per-token gating (eqs. 3-5).

    Shapes: q, k, v are (B, H, T, Dh); gate_logits is (B, H, T, 1) or None.
    Returns (B, H, T, Dh).
    """
    d_head = q.shape[-1]
    scores = jnp.einsum(
        "bhtd,bhsd->bhts", q, k, preferred_element_type=jnp.float32
    ) / jnp.sqrt(jnp.asarray(d_head, dtype=jnp.float32))
    if causal:
        t = scores.shape[-1]
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        scores = jnp.where(mask, scores, jnp.asarray(-1e30, scores.dtype))
    probs = clipped_softmax(scores, gamma, zeta, axis=-1)
    out = jnp.einsum("bhts,bhsd->bhtd", probs, v, preferred_element_type=jnp.float32)
    if gate_logits is not None:
        out = jax.nn.sigmoid(gate_logits) * out
    return out.astype(q.dtype)


def attention_probs_ref(
    q: jax.Array, k: jax.Array, gamma, zeta, *, causal: bool = False
) -> jax.Array:
    """The (clipped) attention probability matrix alone, (B, H, T, T)."""
    d_head = q.shape[-1]
    scores = jnp.einsum(
        "bhtd,bhsd->bhts", q, k, preferred_element_type=jnp.float32
    ) / jnp.sqrt(jnp.asarray(d_head, dtype=jnp.float32))
    if causal:
        t = scores.shape[-1]
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        scores = jnp.where(mask, scores, jnp.asarray(-1e30, scores.dtype))
    return clipped_softmax(scores, gamma, zeta, axis=-1).astype(q.dtype)


def fake_quant_ref(x: jax.Array, scale, zero_point, qmax) -> jax.Array:
    """Eq. (1): uniform affine fake quantization.

    ``s * (clip(round(x/s) + z, 0, qmax) - z)`` with round-to-nearest-even
    (jnp.round). qmax = 2^b - 1 is passed as a runtime value so one lowered
    program serves every bitwidth.
    """
    s = jnp.asarray(scale, dtype=x.dtype)
    z = jnp.asarray(zero_point, dtype=x.dtype)
    q = jnp.clip(jnp.round(x / s) + z, 0.0, jnp.asarray(qmax, dtype=x.dtype))
    return s * (q - z)


def layernorm_ref(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5):
    """Standard LayerNorm over the trailing dimension."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * gamma + beta
