"""L1 Pallas kernel: LayerNorm (forward + backward via custom VJP).

LayerNorm is on the paper's critical path twice over: it is part of the
outlier *mechanism* (Section 3: the FFN output must blow up to survive the
LN normalization and still give softmax a big dynamic range) and it runs in
every block of every model family. The kernel normalizes the trailing
feature dimension of a (rows, d) tile.

Single-block grid: the reductions for d_gamma/d_beta span all rows, and at
the tiny-repro sizes used here the whole tensor fits one tile comfortably
(the TPU version would accumulate partial dγ/dβ across row tiles in VMEM
scratch — noted in DESIGN.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-5


def _ln_fwd_kernel(x_ref, g_ref, b_ref, o_ref):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    xhat = (x - mu) * jax.lax.rsqrt(var + _EPS)
    o_ref[...] = (xhat * g_ref[...] + b_ref[...]).astype(o_ref.dtype)


def _ln_bwd_kernel(x_ref, g_ref, do_ref, dx_ref, dg_ref, db_ref):
    x = x_ref[...]
    gamma = g_ref[...]
    do = do_ref[...]
    d = x.shape[-1]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + _EPS)
    xhat = (x - mu) * rstd
    dgx = do * gamma
    # dx = rstd * (dgx - mean(dgx) - xhat * mean(dgx * xhat))
    m1 = jnp.mean(dgx, axis=-1, keepdims=True)
    m2 = jnp.mean(dgx * xhat, axis=-1, keepdims=True)
    dx_ref[...] = (rstd * (dgx - m1 - xhat * m2)).astype(dx_ref.dtype)
    dg_ref[...] = jnp.sum(do * xhat, axis=0).astype(dg_ref.dtype)
    db_ref[...] = jnp.sum(do, axis=0).astype(db_ref.dtype)


def _full(n, d):
    return pl.BlockSpec((n, d), lambda: (0, 0))


def _vec(d):
    return pl.BlockSpec((d,), lambda: (0,))


def _ln_fwd_call(x2d, gamma, beta):
    n, d = x2d.shape
    return pl.pallas_call(
        _ln_fwd_kernel,
        in_specs=[_full(n, d), _vec(d), _vec(d)],
        out_specs=_full(n, d),
        out_shape=jax.ShapeDtypeStruct((n, d), x2d.dtype),
        interpret=True,
    )(x2d, gamma, beta)


def _ln_bwd_call(x2d, gamma, do):
    n, d = x2d.shape
    return pl.pallas_call(
        _ln_bwd_kernel,
        in_specs=[_full(n, d), _vec(d), _full(n, d)],
        out_specs=(_full(n, d), _vec(d), _vec(d)),
        out_shape=(
            jax.ShapeDtypeStruct((n, d), x2d.dtype),
            jax.ShapeDtypeStruct((d,), x2d.dtype),
            jax.ShapeDtypeStruct((d,), x2d.dtype),
        ),
        interpret=True,
    )(x2d, gamma, do)


@jax.custom_vjp
def _ln_op(x2d, gamma, beta):
    return _ln_fwd_call(x2d, gamma, beta)


def _ln_vjp_fwd(x2d, gamma, beta):
    return _ln_fwd_call(x2d, gamma, beta), (x2d, gamma)


def _ln_vjp_bwd(res, do):
    x2d, gamma = res
    dx, dg, db = _ln_bwd_call(x2d, gamma, do)
    return dx, dg, db


_ln_op.defvjp(_ln_vjp_fwd, _ln_vjp_bwd)


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array) -> jax.Array:
    """LayerNorm over the trailing dimension of ``x`` (any leading rank)."""
    shape = x.shape
    x2d = jnp.reshape(x, (-1, shape[-1]))
    return jnp.reshape(_ln_op(x2d, gamma, beta), shape)
