"""AOT compile path: lower every program of every config to HLO text.

This is the ONLY place python runs in the whole system, and it runs once
(`make artifacts`). The interchange format is HLO *text*, not serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly.

Usage:
    python -m compile.aot --out-dir ../artifacts [--configs a,b,c] [--force]

Outputs per config:
    artifacts/<name>/{init,train_step,eval_step,act_collect,eval_quant}.hlo.txt
    artifacts/<name>/manifest.json
"""

from __future__ import annotations

import argparse
import hashlib
import json
import sys
import time
from pathlib import Path

import jax
from jax._src.lib import xla_client as xc

from .configs import DEFAULT_BUILD, REGISTRY, ModelConfig
from .model import param_specs
from .train import PROGRAM_BUILDERS

MANIFEST_VERSION = 5  # bump to invalidate stale artifact directories (v5: + serve_score)


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# Bump when the manifest *document* changes shape without a program/config
# change (fingerprint-matched artifact dirs skip rebuild, so a new manifest
# key needs this to reach existing artifacts). schema 2: + "version" key.
# schema 3: + "package" block (checksummed entries + provenance).
MANIFEST_SCHEMA = 3

# Version of the "package" block itself (mirrors
# rust/src/runtime/package.rs::PACKAGE_SCHEMA — the rust side fail-closed
# rejects any other value, and treats manifests without the block as the
# read-only legacy tier).
PACKAGE_SCHEMA = 2


def _sha256_file(path: Path) -> tuple[str, int]:
    h = hashlib.sha256()
    data = path.read_bytes()
    h.update(data)
    return h.hexdigest(), len(data)


def _entry_kind(name: str) -> str:
    # Mirrors rust/src/runtime/package.rs::kind_of.
    if name.endswith(".hlo.txt"):
        return "program"
    if name.endswith(".ckpt"):
        return "checkpoint"
    if name.endswith(".json"):
        return "meta"
    return "data"


def package_block(cfg: ModelConfig, cdir: Path, fp: str,
                  quant_points: list[str]) -> dict:
    """The manifest-v2 "package" block: per-entry {path, kind, bytes,
    sha256} over every payload file (the manifest itself is excluded, so
    writing it cannot invalidate checksums) plus a provenance record."""
    entries = []
    for path in sorted(cdir.iterdir()):
        if not path.is_file() or path.name.startswith("."):
            continue
        if path.name == "manifest.json":
            continue
        sha, size = _sha256_file(path)
        entries.append({"path": path.name, "kind": _entry_kind(path.name),
                        "bytes": size, "sha256": sha})
    install_blob = "".join(
        f"{e['path']} {e['bytes']} {e['sha256']}\n" for e in entries)
    variant = f"{cfg.attention}+gate" if cfg.use_gate else cfg.attention
    return {
        "schema": PACKAGE_SCHEMA,
        "install_id": hashlib.sha256(install_blob.encode()).hexdigest()[:16],
        "entries": entries,
        "provenance": {
            "fingerprint": fp,
            "config": cfg.name,
            "variant": variant,
            "calibration_id": hashlib.sha256(
                ",".join(quant_points).encode()).hexdigest()[:16],
            "toolchain": f"aot.py jax-{jax.__version__}",
        },
    }


def config_fingerprint(cfg: ModelConfig) -> str:
    blob = (json.dumps(cfg.to_dict(), sort_keys=True)
            + f"|v{MANIFEST_VERSION}|schema{MANIFEST_SCHEMA}")
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def lower_config(cfg: ModelConfig, out_dir: Path, force: bool = False) -> bool:
    """Lower all programs for one config. Returns True if work was done."""
    cdir = out_dir / cfg.name
    manifest_path = cdir / "manifest.json"
    fp = config_fingerprint(cfg)
    if manifest_path.exists() and not force:
        try:
            old = json.loads(manifest_path.read_text())
            if old.get("fingerprint") == fp and all(
                (cdir / prog["file"]).exists() for prog in old["programs"].values()
            ):
                return False  # up to date
        except (json.JSONDecodeError, KeyError):
            pass
    cdir.mkdir(parents=True, exist_ok=True)

    manifest: dict = {
        # Explicit format version: the rust side compares it against
        # feature gates (e.g. serve needs v5's serve_score) and reports
        # found-vs-required in its "re-run `make artifacts`" errors.
        "version": MANIFEST_VERSION,
        "fingerprint": fp,
        "config": cfg.to_dict(),
        "params": [s.to_dict() for s in param_specs(cfg)],
        "programs": {},
        "quant_points": [],
    }

    for prog_name, builder in PROGRAM_BUILDERS.items():
        t0 = time.time()
        built = builder(cfg)
        if prog_name == "eval_quant":
            fn, inputs, outputs, points = built
            manifest["quant_points"] = points
        else:
            fn, inputs, outputs = built
        specs = [d.spec() for d in inputs]
        # keep_unused: the manifest promises every input is a real program
        # parameter (e.g. b_init on non-gated configs, gate_scale on softmax
        # configs) — never let jit DCE them away.
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{prog_name}.hlo.txt"
        (cdir / fname).write_text(text)
        manifest["programs"][prog_name] = {
            "file": fname,
            "inputs": [d.to_dict() for d in inputs],
            "outputs": [d.to_dict() for d in outputs],
        }
        print(
            f"  [{cfg.name}] {prog_name}: {len(inputs)} in / {len(outputs)} out, "
            f"{len(text) / 1e6:.1f} MB HLO, {time.time() - t0:.1f}s",
            flush=True,
        )

    # Computed last: every program file is on disk, so the entry checksums
    # cover the final payload bytes.
    manifest["package"] = package_block(cfg, cdir, fp, manifest["quant_points"])
    manifest_path.write_text(json.dumps(manifest, indent=1))
    return True


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default=None,
                    help="comma-separated config names (default: all)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true", help="list configs and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name in DEFAULT_BUILD:
            print(name)
        return

    names = args.configs.split(",") if args.configs else DEFAULT_BUILD
    out_dir = Path(args.out_dir)
    t0 = time.time()
    done = skipped = 0
    for name in names:
        if name not in REGISTRY:
            print(f"unknown config {name!r}; use --list", file=sys.stderr)
            sys.exit(2)
        if lower_config(REGISTRY[name], out_dir, force=args.force):
            done += 1
        else:
            skipped += 1
    print(f"artifacts: {done} built, {skipped} up-to-date, "
          f"{time.time() - t0:.1f}s total")


if __name__ == "__main__":
    main()
