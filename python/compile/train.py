"""L2: losses, AdamW, and the five AOT program builders.

Each builder returns ``(fn, input_descs, output_descs)`` where ``fn`` takes a
*flat positional* argument list in exactly the order of ``input_descs`` and
returns a flat tuple in the order of ``output_descs``. ``aot.py`` lowers the
fn and writes the descs into the manifest, which is the rust runtime's only
source of truth for program IO.

Runtime hyperparameter scalars (all f32 unless noted):

  gamma, zeta   clipped-softmax stretch (γ=0, ζ=1 ⇒ vanilla) — Tables 1/5/8
  gate_scale    multiplier on the gate output (2.0 in §B.6 fine-tuning)
  lr            learning-rate for the step (schedule computed in rust)
  wd_ln         0/1 toggle: weight decay on LayerNorm γ (Table 6)
  act_reg       FFN-output L2 activation-regularization coefficient (§B.6)
  qmax          2^bits − 1 activation grid size (Table 10)
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .model import (
    IdentityTap,
    QuantTap,
    RecordTap,
    example_model_input,
    forward,
    init_params,
    param_specs,
    params_to_dict,
    quant_point_names,
)

ADAM_EPS = 1e-8


class IODesc:
    def __init__(self, name: str, shape: tuple[int, ...], dtype: str):
        self.name, self.shape, self.dtype = name, tuple(shape), dtype

    def spec(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, jnp.dtype(self.dtype))

    def to_dict(self) -> dict:
        return {"name": self.name, "shape": list(self.shape), "dtype": self.dtype}


def _scalar(name: str, dtype: str = "float32") -> IODesc:
    return IODesc(name, (), dtype)


def _param_descs(cfg: ModelConfig, prefix: str) -> list[IODesc]:
    return [IODesc(f"{prefix}::{s.name}", s.shape, "float32") for s in param_specs(cfg)]


def _batch_descs(cfg: ModelConfig) -> list[IODesc]:
    b, t = cfg.batch_size, cfg.seq_len
    if cfg.family == "vit":
        return [
            IODesc("batch::x", (b, t - 1, cfg.patch_dim), "float32"),
            IODesc("batch::targets", (b,), "int32"),
        ]
    return [
        IODesc("batch::x", (b, t), "int32"),
        IODesc("batch::targets", (b, t), "int32"),
        IODesc("batch::mask", (b, t), "float32"),
    ]


def _token_loss(logits: jax.Array, targets: jax.Array, mask: jax.Array):
    """Masked token-level cross entropy. Returns (sum_nll, count, correct)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    sum_nll = jnp.sum(nll * mask)
    count = jnp.sum(mask)
    pred = jnp.argmax(logits, axis=-1)
    correct = jnp.sum((pred == targets).astype(jnp.float32) * mask)
    return sum_nll, count, correct


def _token_loss_rows(logits: jax.Array, targets: jax.Array, mask: jax.Array):
    """Per-example masked token cross entropy: (row_nll, row_count,
    row_correct), each shaped (batch,). The serving path needs per-row
    results so one batched program invocation can be split back into
    independent client responses."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    row_nll = jnp.sum(nll * mask, axis=1)
    row_count = jnp.sum(mask, axis=1)
    pred = jnp.argmax(logits, axis=-1)
    row_correct = jnp.sum((pred == targets).astype(jnp.float32) * mask, axis=1)
    return row_nll, row_count, row_correct


def _cls_loss(logits: jax.Array, targets: jax.Array):
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[:, None], axis=-1)[:, 0]
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == targets).astype(jnp.float32))
    return jnp.sum(nll), jnp.asarray(nll.shape[0], jnp.float32), correct


def _loss_from_batch(cfg: ModelConfig, logits, batch):
    if cfg.family == "vit":
        return _cls_loss(logits, batch[1])
    return _token_loss(logits, batch[1], batch[2])


# --------------------------------------------------------------------------
# Programs
# --------------------------------------------------------------------------

def build_init(cfg: ModelConfig):
    inputs = [_scalar("seed", "int32"), _scalar("b_init")]
    outputs = _param_descs(cfg, "param")

    def fn(seed, b_init):
        return tuple(init_params(cfg, seed, b_init))

    return fn, inputs, outputs


def build_train_step(cfg: ModelConfig):
    """One fused AdamW step: fwd, bwd, global-norm clip, decoupled decay.

    State layout: params, first moment (m), second moment (v), step counter.
    The step counter drives bias correction; the LR schedule itself lives in
    rust (lr arrives as an input), keeping all schedule experiments out of
    the artifact.
    """
    specs = param_specs(cfg)
    n = len(specs)
    inputs = (
        _param_descs(cfg, "param")
        + _param_descs(cfg, "m")
        + _param_descs(cfg, "v")
        + [_scalar("step")]
        + _batch_descs(cfg)
        + [_scalar("lr"), _scalar("gamma"), _scalar("zeta"),
           _scalar("gate_scale"), _scalar("wd_ln"), _scalar("act_reg")]
    )
    outputs = (
        _param_descs(cfg, "param")
        + _param_descs(cfg, "m")
        + _param_descs(cfg, "v")
        + [_scalar("step"), _scalar("loss")]
    )
    nb = len(_batch_descs(cfg))

    def fn(*args):
        params = list(args[0:n])
        m = list(args[n:2 * n])
        v = list(args[2 * n:3 * n])
        step = args[3 * n]
        batch = args[3 * n + 1:3 * n + 1 + nb]
        lr, gamma, zeta, gate_scale, wd_ln, act_reg = args[3 * n + 1 + nb:]

        def loss_fn(plist):
            pdict = params_to_dict(cfg, plist)
            rec = RecordTap()
            logits = forward(cfg, pdict, batch[0], gamma, zeta, gate_scale,
                             tap=rec)
            sum_nll, count, _ = _loss_from_batch(cfg, logits, batch)
            loss = sum_nll / jnp.maximum(count, 1.0)
            # §B.6 activation regularization on FFN outputs (0 when act_reg=0).
            reg = sum(
                jnp.mean(jnp.square(t_))
                for name, t_ in rec.records.items()
                if name.endswith(".ffn_out")
            ) / cfg.n_layers
            return loss + act_reg * reg, loss

        (_total, loss), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)

        # Global-norm gradient clipping (1.0 in all paper setups, §C).
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads))
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))
        grads = [g * scale for g in grads]

        step1 = step + 1.0
        b1, b2 = cfg.adam_b1, cfg.adam_b2
        bc1 = 1.0 - jnp.power(b1, step1)
        bc2 = 1.0 - jnp.power(b2, step1)
        new_p, new_m, new_v = [], [], []
        for spec, pp, mm, vv, gg in zip(specs, params, m, v, grads):
            mm = b1 * mm + (1.0 - b1) * gg
            vv = b2 * vv + (1.0 - b2) * jnp.square(gg)
            upd = (mm / bc1) / (jnp.sqrt(vv / bc2) + ADAM_EPS)
            wd = cfg.weight_decay if spec.decay else 0.0
            wd_dynamic = cfg.weight_decay * wd_ln if spec.ln_gamma else wd
            pp = pp - lr * (upd + wd_dynamic * pp)
            new_p.append(pp)
            new_m.append(mm)
            new_v.append(vv)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (step1, loss)

    return fn, inputs, outputs


def build_eval_step(cfg: ModelConfig):
    inputs = (
        _param_descs(cfg, "param")
        + _batch_descs(cfg)
        + [_scalar("gamma"), _scalar("zeta"), _scalar("gate_scale")]
    )
    outputs = [_scalar("sum_nll"), _scalar("count"), _scalar("correct")]
    n = len(param_specs(cfg))
    nb = len(_batch_descs(cfg))

    def fn(*args):
        pdict = params_to_dict(cfg, list(args[0:n]))
        batch = args[n:n + nb]
        gamma, zeta, gate_scale = args[n + nb:]
        logits = forward(cfg, pdict, batch[0], gamma, zeta, gate_scale)
        return _loss_from_batch(cfg, logits, batch)

    return fn, inputs, outputs


def build_act_collect(cfg: ModelConfig):
    """Collect every tapped activation (quant points + analysis tensors) for
    one batch — feeds the rust calibrator (ranges), the §5 outlier metrics,
    and the Fig 1-3/8 analysis dumps."""
    inputs = (
        _param_descs(cfg, "param")
        + _batch_descs(cfg)
        + [_scalar("gamma"), _scalar("zeta"), _scalar("gate_scale")]
    )
    n = len(param_specs(cfg))
    nb = len(_batch_descs(cfg))

    # Determine tap names/shapes by abstract evaluation.
    def raw(*args):
        pdict = params_to_dict(cfg, list(args[0:n]))
        batch = args[n:n + nb]
        gamma, zeta, gate_scale = args[n + nb:]
        rec = RecordTap()
        logits = forward(cfg, pdict, batch[0], gamma, zeta, gate_scale,
                         tap=rec, decompose_attention=True)
        sum_nll, count, correct = _loss_from_batch(cfg, logits, batch)
        return rec.records, (sum_nll, count, correct)

    in_specs = [d.spec() for d in inputs]
    shaped = jax.eval_shape(raw, *in_specs)
    names = list(shaped[0].keys())
    outputs = [
        IODesc(f"act::{k}", shaped[0][k].shape, "float32") for k in names
    ] + [_scalar("sum_nll"), _scalar("count"), _scalar("correct")]

    def fn(*args):
        records, stats = raw(*args)
        return tuple(records[k] for k in names) + stats

    return fn, inputs, outputs


def build_eval_quant(cfg: ModelConfig):
    """Quantized evaluation: weights arrive already fake-quantized (rust does
    symmetric weight PTQ on the host); activations are fake-quantized
    in-graph at every quant point with runtime scale/zero-point vectors and
    runtime qmax (so one artifact serves W*A8/A6/A4 — Table 10)."""
    points = quant_point_names(cfg)
    idx = {nm: i for i, nm in enumerate(points)}
    npts = len(points)
    inputs = (
        _param_descs(cfg, "param")
        + [IODesc("act_scale", (npts,), "float32"),
           IODesc("act_zp", (npts,), "float32"),
           _scalar("qmax")]
        + _batch_descs(cfg)
        + [_scalar("gamma"), _scalar("zeta"), _scalar("gate_scale")]
    )
    outputs = [_scalar("sum_nll"), _scalar("count"), _scalar("correct")]
    n = len(param_specs(cfg))
    nb = len(_batch_descs(cfg))

    def fn(*args):
        pdict = params_to_dict(cfg, list(args[0:n]))
        scales, zps, qmax = args[n:n + 3]
        batch = args[n + 3:n + 3 + nb]
        gamma, zeta, gate_scale = args[n + 3 + nb:]
        tap = QuantTap(idx, scales, zps, qmax)
        logits = forward(cfg, pdict, batch[0], gamma, zeta, gate_scale,
                         tap=tap, decompose_attention=True)
        return _loss_from_batch(cfg, logits, batch)

    return fn, inputs, outputs, points


def build_serve_score(cfg: ModelConfig):
    """Per-example quantized scoring for the serving path (`qtx serve`).

    Same in-graph activation fake-quant as ``eval_quant`` (runtime
    scale/zero-point vectors + runtime qmax over the shared quant-point
    list), but the outputs are per-row (nll, count, correct) vectors so the
    dynamic micro-batcher can pack several independent client requests into
    one static-shape invocation and split the results back out. Padding rows
    carry an all-zero mask and therefore score (0, 0, 0).
    """
    points = quant_point_names(cfg)
    idx = {nm: i for i, nm in enumerate(points)}
    npts = len(points)
    b = cfg.batch_size
    inputs = (
        _param_descs(cfg, "param")
        + [IODesc("act_scale", (npts,), "float32"),
           IODesc("act_zp", (npts,), "float32"),
           _scalar("qmax")]
        + _batch_descs(cfg)
        + [_scalar("gamma"), _scalar("zeta"), _scalar("gate_scale")]
    )
    outputs = [
        IODesc("nll", (b,), "float32"),
        IODesc("count", (b,), "float32"),
        IODesc("correct", (b,), "float32"),
    ]
    n = len(param_specs(cfg))
    nb = len(_batch_descs(cfg))

    def fn(*args):
        pdict = params_to_dict(cfg, list(args[0:n]))
        scales, zps, qmax = args[n:n + 3]
        batch = args[n + 3:n + 3 + nb]
        gamma, zeta, gate_scale = args[n + 3 + nb:]
        tap = QuantTap(idx, scales, zps, qmax)
        logits = forward(cfg, pdict, batch[0], gamma, zeta, gate_scale,
                         tap=tap, decompose_attention=True)
        if cfg.family == "vit":
            targets = batch[1]
            logp = jax.nn.log_softmax(logits, axis=-1)
            row_nll = -jnp.take_along_axis(logp, targets[:, None], axis=-1)[:, 0]
            row_count = jnp.ones((b,), jnp.float32)
            row_correct = (jnp.argmax(logits, axis=-1) == targets).astype(jnp.float32)
            return row_nll, row_count, row_correct
        return _token_loss_rows(logits, batch[1], batch[2])

    return fn, inputs, outputs


PROGRAM_BUILDERS: dict[str, Callable] = {
    "init": build_init,
    "train_step": build_train_step,
    "eval_step": build_eval_step,
    "act_collect": build_act_collect,
    "eval_quant": build_eval_quant,
    "serve_score": build_serve_score,
}
