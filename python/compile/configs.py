"""Model / experiment configuration registry (mirrored into manifests).

Every named config here becomes one artifact directory
(``artifacts/<name>/``) holding the five AOT programs plus a manifest. The
rust coordinator only ever consumes the manifest — this module is the single
source of truth for shapes.

Families follow the paper's three testbeds, scaled to this CPU testbed (see
DESIGN.md "Hardware adaptation"):

  * ``bert_*`` — post-LN encoder, MLM objective (paper §5 "BERT").
  * ``opt_*``  — pre-LN causal decoder, CLM objective (paper §5 "OPT").
  * ``vit_*``  — pre-LN encoder over patch embeddings + CLS classification
                 (paper §5 "ViT"), with the optional patch-embedding
                 LayerNorm ablation of Table 7.

Attention variants: ``softmax`` covers BOTH vanilla and clipped softmax
(gamma/zeta are runtime inputs; gamma=0, zeta=1 is exactly vanilla), and the
three gating architectures of Table 4 are separate configs because they
change the parameter set.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

ATTENTION_VARIANTS = ("softmax", "gated_linear", "gated_mlp", "gated_allheads")
FAMILIES = ("bert", "opt", "vit")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # bert | opt | vit
    attention: str  # see ATTENTION_VARIANTS
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    seq_len: int  # T; for vit: n_patches + 1 (CLS)
    vocab_size: int = 0  # bert/opt
    n_classes: int = 0  # vit
    patch_dim: int = 0  # vit: patch_size^2 * channels
    patch_ln: bool = False  # vit Table 7 ablation
    ln_placement: str = "post"  # post (bert) | pre (opt, vit)
    causal: bool = False
    objective: str = "mlm"  # mlm | clm | cls
    batch_size: int = 32
    gate_hidden: int = 4  # MLP gating hidden width (Table 4)
    init_std: float = 0.02  # 0.006 for OPT per §C.2
    adam_b1: float = 0.9
    adam_b2: float = 0.999  # (0.9, 0.95) for OPT per §C.2
    weight_decay: float = 0.01  # 0.1 OPT, 0.03 ViT
    grad_clip: float = 1.0

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def use_gate(self) -> bool:
        return self.attention.startswith("gated")

    def validate(self) -> None:
        assert self.family in FAMILIES, self.family
        assert self.attention in ATTENTION_VARIANTS, self.attention
        assert self.d_model % self.n_heads == 0
        assert self.ln_placement in ("pre", "post")
        assert self.objective in ("mlm", "clm", "cls")
        if self.family == "vit":
            assert self.n_classes > 0 and self.patch_dim > 0
        else:
            assert self.vocab_size > 0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["d_head"] = self.d_head
        d["use_gate"] = self.use_gate
        return d


def _bert(name: str, attention: str, *, n_layers=4, d_model=64, n_heads=4,
          seq_len=64, vocab_size=256, batch_size=32) -> ModelConfig:
    return ModelConfig(
        name=name, family="bert", attention=attention, n_layers=n_layers,
        d_model=d_model, n_heads=n_heads, d_ff=4 * d_model, seq_len=seq_len,
        vocab_size=vocab_size, ln_placement="post", causal=False,
        objective="mlm", batch_size=batch_size, init_std=0.02,
        adam_b2=0.999, weight_decay=0.01,
    )


def _opt(name: str, attention: str, *, n_layers=4, d_model=64, n_heads=4,
         seq_len=64, vocab_size=256, batch_size=16) -> ModelConfig:
    return ModelConfig(
        name=name, family="opt", attention=attention, n_layers=n_layers,
        d_model=d_model, n_heads=n_heads, d_ff=4 * d_model, seq_len=seq_len,
        vocab_size=vocab_size, ln_placement="pre", causal=True,
        objective="clm", batch_size=batch_size, init_std=0.006,
        adam_b2=0.95, weight_decay=0.1,
    )


def _vit(name: str, attention: str, *, n_layers=4, d_model=64, n_heads=4,
         n_patches=16, patch_dim=64, n_classes=8, batch_size=32,
         patch_ln=False) -> ModelConfig:
    return ModelConfig(
        name=name, family="vit", attention=attention, n_layers=n_layers,
        d_model=d_model, n_heads=n_heads, d_ff=4 * d_model,
        seq_len=n_patches + 1, n_classes=n_classes, patch_dim=patch_dim,
        patch_ln=patch_ln, ln_placement="pre", causal=False, objective="cls",
        batch_size=batch_size, init_std=0.02, adam_b2=0.999,
        weight_decay=0.03,
    )


def build_registry() -> dict[str, ModelConfig]:
    cfgs: list[ModelConfig] = []

    # --- BERT family (Tables 1, 2, 5, 10; Figs 1, 2, 7, 8) ---------------
    for att in ATTENTION_VARIANTS:
        cfgs.append(_bert(f"bert_tiny_{att}", att))
    # Table 5 "GA, MLP (n_hid=64)" ablation, scaled (n_hid 4 -> 16).
    big_mlp = dataclasses.replace(_bert("bert_tiny_gated_mlp16", "gated_mlp"),
                                  gate_hidden=16)
    cfgs.append(big_mlp)

    # BERT-6L sequence-length sweep (Fig 6): gamma = -alpha/T is a runtime
    # input, so only T varies structurally.
    for t in (16, 32, 64):
        cfgs.append(_bert(f"bert6l_t{t}_softmax", "softmax", n_layers=6,
                          d_model=64, seq_len=t, batch_size=32))
    # Fig 7 (b_init sweep) reuses bert6l_t64 gated config.
    cfgs.append(_bert("bert6l_t64_gated_linear", "gated_linear", n_layers=6,
                      d_model=64, seq_len=64, batch_size=32))

    # --- OPT family (Tables 2, 3, 6, 9) ----------------------------------
    for att in ("softmax", "gated_linear", "gated_allheads"):
        cfgs.append(_opt(f"opt_tiny_{att}", att))
    # "Bigger variants" of Table 3, scaled to this testbed.
    for att in ("softmax", "gated_linear"):
        cfgs.append(_opt(f"opt_mid_{att}", att, n_layers=6, d_model=96,
                         n_heads=6))
        cfgs.append(_opt(f"opt_big_{att}", att, n_layers=8, d_model=128,
                         n_heads=8, batch_size=8))

    # --- ViT family (Tables 2, 7, 8; Fig 3, 7) ---------------------------
    for att in ("softmax", "gated_linear", "gated_mlp"):
        cfgs.append(_vit(f"vit_tiny_{att}", att))
        cfgs.append(_vit(f"vit_tiny_{att}_patchln", att, patch_ln=True))

    registry = {}
    for c in cfgs:
        c.validate()
        assert c.name not in registry, f"duplicate config {c.name}"
        registry[c.name] = c
    return registry


REGISTRY = build_registry()

# The subset built by a default `make artifacts` (everything; kept explicit
# so CI-style smoke builds can trim it with --configs).
DEFAULT_BUILD = sorted(REGISTRY)
