"""L2: the transformer model (forward graph) for all three families.

Plain-JAX (no flax): parameters are an ordered ``name -> array`` mapping
whose canonical order is defined by :func:`param_specs`. The forward pass
calls the L1 Pallas kernels (``kernels.attention``, ``kernels.layernorm``)
so they lower into the same AOT HLO the rust runtime executes.

Activation *taps* are the mechanism behind both quantization simulation and
the activation-analysis programs: every quantizable activation (paper §5
"Quantization setup": all activations except after the final linear layer)
flows through ``tap(name, x)``. The tap is

  * identity                       for train/eval programs,
  * a fake-quant wrapper           for the ``eval_quant`` program,
  * a recorder                     for the ``act_collect`` program.

The tap-call order is deterministic, which gives a stable quant-point index
shared between the manifest and the rust calibrator.
"""

from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.attention import attention, attention_probs
from .kernels.fake_quant import fake_quant
from .kernels.layernorm import layernorm

Params = dict[str, jax.Array]


# --------------------------------------------------------------------------
# Parameter specification
# --------------------------------------------------------------------------

class ParamSpec:
    """Static description of one parameter tensor.

    ``init``: one of normal | zeros | ones | he | gate_bias.
    ``decay``: subject to L2 weight decay (paper: weights yes; biases and
    LN params no — except LN gammas when the ``wd_ln`` runtime toggle of
    Table 6 is on, flagged by ``ln_gamma``).
    ``quantize``: weight-quantized by the rust PTQ pipeline (2D+ weights and
    embeddings; the final head is excluded per §5).
    """

    def __init__(self, name: str, shape: tuple[int, ...], init: str = "normal",
                 decay: bool = False, quantize: bool = False,
                 ln_gamma: bool = False):
        self.name = name
        self.shape = shape
        self.init = init
        self.decay = decay
        self.quantize = quantize
        self.ln_gamma = ln_gamma

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "shape": list(self.shape),
            "init": self.init,
            "decay": self.decay,
            "quantize": self.quantize,
            "ln_gamma": self.ln_gamma,
        }


def param_specs(cfg: ModelConfig) -> list[ParamSpec]:
    """The canonical, ordered parameter list for a config."""
    d, ff, t = cfg.d_model, cfg.d_ff, cfg.seq_len
    h, dh = cfg.n_heads, cfg.d_head
    specs: list[ParamSpec] = []

    def p(*args, **kw):
        specs.append(ParamSpec(*args, **kw))

    # Embeddings.
    if cfg.family == "vit":
        p("patch_emb.w", (cfg.patch_dim, d), "normal", decay=True, quantize=True)
        p("patch_emb.b", (d,), "zeros")
        p("cls_token", (d,), "normal")
        p("pos_emb", (t, d), "normal", quantize=True)
        if cfg.patch_ln:
            p("patch_ln.g", (d,), "ones", ln_gamma=True)
            p("patch_ln.b", (d,), "zeros")
    else:
        p("tok_emb", (cfg.vocab_size, d), "normal", decay=True, quantize=True)
        p("pos_emb", (t, d), "normal", quantize=True)
        if cfg.family == "bert":
            p("emb_ln.g", (d,), "ones", ln_gamma=True)
            p("emb_ln.b", (d,), "zeros")

    # Transformer blocks.
    for i in range(cfg.n_layers):
        L = f"L{i}"
        p(f"{L}.wq", (d, d), "normal", decay=True, quantize=True)
        p(f"{L}.bq", (d,), "zeros")
        p(f"{L}.wk", (d, d), "normal", decay=True, quantize=True)
        p(f"{L}.bk", (d,), "zeros")
        p(f"{L}.wv", (d, d), "normal", decay=True, quantize=True)
        p(f"{L}.bv", (d,), "zeros")
        p(f"{L}.wo", (d, d), "normal", decay=True, quantize=True)
        p(f"{L}.bo", (d,), "zeros")
        if cfg.attention == "gated_linear":
            p(f"{L}.gate.w", (h, dh), "he", decay=True)
            p(f"{L}.gate.b", (h,), "gate_bias")
        elif cfg.attention == "gated_mlp":
            p(f"{L}.gate.w1", (h, dh, cfg.gate_hidden), "he", decay=True)
            p(f"{L}.gate.b1", (h, cfg.gate_hidden), "zeros")
            p(f"{L}.gate.w2", (h, cfg.gate_hidden), "he", decay=True)
            p(f"{L}.gate.b2", (h,), "gate_bias")
        elif cfg.attention == "gated_allheads":
            p(f"{L}.gate.w", (d, h), "he", decay=True)
            p(f"{L}.gate.b", (h,), "gate_bias")
        p(f"{L}.ln1.g", (d,), "ones", ln_gamma=True)
        p(f"{L}.ln1.b", (d,), "zeros")
        p(f"{L}.w1", (d, ff), "normal", decay=True, quantize=True)
        p(f"{L}.b1", (ff,), "zeros")
        p(f"{L}.w2", (ff, d), "normal", decay=True, quantize=True)
        p(f"{L}.b2", (d,), "zeros")
        p(f"{L}.ln2.g", (d,), "ones", ln_gamma=True)
        p(f"{L}.ln2.b", (d,), "zeros")

    if cfg.ln_placement == "pre":
        p("final_ln.g", (d,), "ones", ln_gamma=True)
        p("final_ln.b", (d,), "zeros")

    # Output head — excluded from quantization per §5.
    out_dim = cfg.n_classes if cfg.family == "vit" else cfg.vocab_size
    p("head.w", (d, out_dim), "normal", decay=True, quantize=False)
    p("head.b", (out_dim,), "zeros")
    return specs


def init_params(cfg: ModelConfig, seed, b_init) -> list[jax.Array]:
    """Initialize the parameter list (traceable: seed/b_init may be traced).

    normal: N(0, init_std) following §C.1/C.2; he: He-normal fan-in init for
    gating weights (paper §5.3 cites He et al. [22]); gate_bias: the b_init
    runtime input controlling the initial gate openness pi_init (Fig 7).
    """
    key = jax.random.PRNGKey(seed)
    out = []
    for idx, spec in enumerate(param_specs(cfg)):
        k = jax.random.fold_in(key, idx)
        if spec.init == "normal":
            arr = cfg.init_std * jax.random.normal(k, spec.shape, jnp.float32)
        elif spec.init == "he":
            fan_in = spec.shape[-1] if len(spec.shape) <= 2 else spec.shape[-2]
            std = math.sqrt(2.0 / fan_in)
            arr = std * jax.random.normal(k, spec.shape, jnp.float32)
        elif spec.init == "zeros":
            arr = jnp.zeros(spec.shape, jnp.float32)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, jnp.float32)
        elif spec.init == "gate_bias":
            arr = jnp.full(spec.shape, jnp.asarray(b_init, jnp.float32))
        else:  # pragma: no cover
            raise ValueError(spec.init)
        out.append(arr)
    return out


def params_to_dict(cfg: ModelConfig, flat: list[jax.Array]) -> Params:
    specs = param_specs(cfg)
    assert len(specs) == len(flat), (len(specs), len(flat))
    return {s.name: a for s, a in zip(specs, flat)}


# --------------------------------------------------------------------------
# Taps (identity / fake-quant / record)
# --------------------------------------------------------------------------

class IdentityTap:
    def __call__(self, name: str, x: jax.Array) -> jax.Array:
        return x


class RecordTap:
    """Records every tapped activation; used to enumerate quant points and
    by the act_collect program."""

    def __init__(self):
        self.records: dict[str, jax.Array] = {}

    def __call__(self, name: str, x: jax.Array) -> jax.Array:
        assert name not in self.records, f"duplicate tap {name}"
        self.records[name] = x
        return x


class QuantTap:
    """Applies the L1 fake-quant kernel at every quant point, with per-point
    scale/zero-point taken from runtime input vectors (asymmetric static
    activation quantization, §5)."""

    def __init__(self, point_index: dict[str, int], scales: jax.Array,
                 zps: jax.Array, qmax: jax.Array):
        self.point_index = point_index
        self.scales = scales
        self.zps = zps
        self.qmax = qmax

    def __call__(self, name: str, x: jax.Array) -> jax.Array:
        if name not in self.point_index:  # analysis-only taps pass through
            return x
        i = self.point_index[name]
        return fake_quant(x, self.scales[i], self.zps[i], self.qmax)


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------

def _gate_logits(cfg: ModelConfig, p: Params, layer: int, xh: jax.Array):
    """Gating module G (Table 4 architectures). xh: (B, H, T, Dh) per-head
    input (gates are shared across positions, not across heads — §4.2)."""
    L = f"L{layer}"
    if cfg.attention == "gated_linear":
        g = jnp.einsum("bhtd,hd->bht", xh, p[f"{L}.gate.w"]) + p[f"{L}.gate.b"][None, :, None]
    elif cfg.attention == "gated_mlp":
        hmid = jnp.einsum("bhtd,hdk->bhtk", xh, p[f"{L}.gate.w1"])
        hmid = jax.nn.relu(hmid + p[f"{L}.gate.b1"][None, :, None, :])
        g = jnp.einsum("bhtk,hk->bht", hmid, p[f"{L}.gate.w2"]) + p[f"{L}.gate.b2"][None, :, None]
    elif cfg.attention == "gated_allheads":
        b, h, t, dh = xh.shape
        flat = jnp.reshape(jnp.transpose(xh, (0, 2, 1, 3)), (b, t, h * dh))
        g = jnp.transpose(flat @ p["%s.gate.w" % L] + p[f"{L}.gate.b"], (0, 2, 1))
    else:  # pragma: no cover
        raise ValueError(cfg.attention)
    return g[..., None]  # (B, H, T, 1)


def _split_heads(x: jax.Array, h: int) -> jax.Array:
    b, t, d = x.shape
    return jnp.transpose(jnp.reshape(x, (b, t, h, d // h)), (0, 2, 1, 3))


def _merge_heads(x: jax.Array) -> jax.Array:
    b, h, t, dh = x.shape
    return jnp.reshape(jnp.transpose(x, (0, 2, 1, 3)), (b, t, h * dh))


def _ln(p: Params, prefix: str, x: jax.Array) -> jax.Array:
    return layernorm(x, p[f"{prefix}.g"], p[f"{prefix}.b"])


def forward(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    gamma,
    zeta,
    gate_scale,
    tap: Callable[[str, jax.Array], jax.Array] | None = None,
    *,
    decompose_attention: bool = False,
) -> jax.Array:
    """Run the model; returns logits (B, T, V) or (B, n_classes) for vit.

    decompose_attention=True computes the probability matrix explicitly
    (attention_probs kernel + matmul) so the probs can be tapped — used by
    act_collect (Figs 1-3, 8 need P, V, P·V) and eval_quant (P is itself a
    quantized activation). The fused kernel path is numerically identical.
    """
    tap = tap or IdentityTap()
    gamma = jnp.asarray(gamma, jnp.float32)
    zeta = jnp.asarray(zeta, jnp.float32)
    gate_scale = jnp.asarray(gate_scale, jnp.float32)

    # ---- embeddings ----
    if cfg.family == "vit":
        hthis = x @ p["patch_emb.w"] + p["patch_emb.b"]
        if cfg.patch_ln:
            hthis = _ln(p, "patch_ln", hthis)
        b = hthis.shape[0]
        cls = jnp.broadcast_to(p["cls_token"], (b, 1, cfg.d_model))
        hthis = jnp.concatenate([cls, hthis], axis=1) + p["pos_emb"][None]
    else:
        hthis = p["tok_emb"][x] + p["pos_emb"][None]
        if cfg.family == "bert":
            hthis = _ln(p, "emb_ln", hthis)
    hthis = tap("embed", hthis)

    # ---- blocks ----
    for i in range(cfg.n_layers):
        L = f"L{i}"
        resid = hthis
        xin = _ln(p, f"{L}.ln1", hthis) if cfg.ln_placement == "pre" else hthis

        q = tap(f"{L}.q", xin @ p[f"{L}.wq"] + p[f"{L}.bq"])
        k = tap(f"{L}.k", xin @ p[f"{L}.wk"] + p[f"{L}.bk"])
        v = tap(f"{L}.v", xin @ p[f"{L}.wv"] + p[f"{L}.bv"])
        qh, kh, vh = (_split_heads(t_, cfg.n_heads) for t_ in (q, k, v))
        xh = _split_heads(xin, cfg.n_heads)
        glog = _gate_logits(cfg, p, i, xh) if cfg.use_gate else None

        if decompose_attention:
            probs = attention_probs(qh, kh, gamma, zeta, causal=cfg.causal)
            probs = tap(f"{L}.probs", probs)
            tap(f"{L}.values", vh)  # analysis-only tap (same tensor as .v)
            ctx = jnp.einsum("bhts,bhsd->bhtd", probs, vh,
                             preferred_element_type=jnp.float32)
            if cfg.use_gate:
                gp = jax.nn.sigmoid(glog)
                tap(f"{L}.gate_probs", gp[..., 0])
                ctx = gp * ctx
        else:
            ctx = attention(qh, kh, vh, gamma, zeta, gate_logits=glog,
                            causal=cfg.causal)
        if cfg.use_gate:
            ctx = gate_scale * ctx  # x2 at fine-tuning time, §B.6
        ctx = tap(f"{L}.ctx", ctx)

        attn_out = tap(f"{L}.attn_out", _merge_heads(ctx) @ p[f"{L}.wo"] + p[f"{L}.bo"])
        res1 = tap(f"{L}.res1", resid + attn_out)
        if cfg.ln_placement == "post":
            res1 = tap(f"{L}.ln1_out", _ln(p, f"{L}.ln1", res1))
            fin = res1
        else:
            fin = tap(f"{L}.ln2_out", _ln(p, f"{L}.ln2", res1))

        ffn_h = tap(f"{L}.ffn_h", jax.nn.gelu(fin @ p[f"{L}.w1"] + p[f"{L}.b1"]))
        ffn_out = tap(f"{L}.ffn_out", ffn_h @ p[f"{L}.w2"] + p[f"{L}.b2"])
        res2 = tap(f"{L}.res2", res1 + ffn_out)
        if cfg.ln_placement == "post":
            res2 = tap(f"{L}.ln2_out", _ln(p, f"{L}.ln2", res2))
        # Block output: the tensor the paper's inf-norm/kurtosis metrics are
        # computed on ("the output of an attention layer", §5).
        hthis = tap(f"{L}.block_out", res2)

    if cfg.ln_placement == "pre":
        hthis = tap("final_out", _ln(p, "final_ln", hthis))

    # ---- head (not quantized) ----
    if cfg.family == "vit":
        return hthis[:, 0] @ p["head.w"] + p["head.b"]
    return hthis @ p["head.w"] + p["head.b"]


def quant_point_names(cfg: ModelConfig) -> list[str]:
    """Ordered activation quant points = taps hit by the decomposed forward,
    minus analysis-only taps (values/gate_probs duplicate other tensors, and
    block_out aliases res2/ln2_out)."""
    skip_suffix = (".values", ".gate_probs", ".block_out")
    rec = RecordTap()
    specs = param_specs(cfg)
    p = {s.name: jnp.zeros(s.shape, jnp.float32) for s in specs}
    x = example_model_input(cfg, batch=2)
    forward(cfg, p, x, 0.0, 1.0, 1.0, rec, decompose_attention=True)
    return [n for n in rec.records if not n.endswith(skip_suffix)]


def example_model_input(cfg: ModelConfig, batch: int | None = None):
    b = batch or cfg.batch_size
    if cfg.family == "vit":
        return jnp.zeros((b, cfg.seq_len - 1, cfg.patch_dim), jnp.float32)
    return jnp.zeros((b, cfg.seq_len), jnp.int32)
