"""L2 training-program tests: the AdamW train_step, eval_step, act_collect
and eval_quant builders behave as the manifest promises."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import train as T
from tests.conftest import micro_config, micro_opt, micro_vit


def build_state(cfg, seed=0, b_init=0.0):
    init_fn, _, _ = T.build_init(cfg)
    params = list(init_fn(jnp.int32(seed), jnp.float32(b_init)))
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    return params, m, v


def batch_for(cfg, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.family == "vit":
        x = jnp.asarray(
            rng.normal(size=(cfg.batch_size, cfg.seq_len - 1, cfg.patch_dim)),
            jnp.float32,
        )
        y = jnp.asarray(rng.integers(0, cfg.n_classes, (cfg.batch_size,)), jnp.int32)
        return [x, y]
    toks = jnp.asarray(
        rng.integers(6, cfg.vocab_size, (cfg.batch_size, cfg.seq_len)), jnp.int32
    )
    mask = jnp.asarray(rng.random((cfg.batch_size, cfg.seq_len)) < 0.3, jnp.float32)
    return [toks, toks, mask]


def run_steps(cfg, n_steps, lr=3e-3, **hyper):
    fn, inputs, outputs = T.build_train_step(cfg)
    jfn = jax.jit(fn, keep_unused=True)
    params, m, v = build_state(cfg)
    np_ = len(params)
    step = jnp.float32(0)
    batch = batch_for(cfg)
    h = dict(lr=lr, gamma=0.0, zeta=1.0, gate_scale=1.0, wd_ln=0.0, act_reg=0.0)
    h.update(hyper)
    losses = []
    for i in range(n_steps):
        args = (
            params + m + v + [step] + batch
            + [jnp.float32(h["lr"]), jnp.float32(h["gamma"]), jnp.float32(h["zeta"]),
               jnp.float32(h["gate_scale"]), jnp.float32(h["wd_ln"]),
               jnp.float32(h["act_reg"])]
        )
        out = jfn(*args)
        params = list(out[:np_])
        m = list(out[np_:2 * np_])
        v = list(out[2 * np_:3 * np_])
        step = out[3 * np_]
        losses.append(float(out[-1]))
    return losses, params, step


class TestTrainStep:
    @pytest.mark.parametrize("maker", [micro_config, micro_opt, micro_vit])
    def test_loss_decreases(self, maker):
        cfg = maker()
        losses, _, step = run_steps(cfg, 12)
        assert losses[-1] < losses[0], losses
        assert float(step) == 12.0
        assert all(np.isfinite(losses))

    def test_io_descs_cover_args(self):
        cfg = micro_config()
        _, inputs, outputs = T.build_train_step(cfg)
        n = len(M.param_specs(cfg))
        in_names = [d.name for d in inputs]
        assert in_names.count("lr") == 1
        assert sum(x.startswith("param::") for x in in_names) == n
        assert sum(x.startswith("m::") for x in in_names) == n
        out_names = [d.name for d in outputs]
        assert out_names[-1] == "loss" and out_names[-2] == "step"
        # outputs mirror the state inputs exactly, in order
        assert out_names[: 3 * n + 1] == in_names[: 3 * n + 1]

    def test_wd_ln_toggle_shrinks_ln_gamma(self):
        """Table 6 ablation: with wd_ln=1 LayerNorm γ decays, without it
        stays near 1 under zero-gradient-ish conditions."""
        cfg = micro_opt()
        specs = M.param_specs(cfg)
        gi = next(i for i, s in enumerate(specs) if s.ln_gamma)
        _, p_off, _ = run_steps(cfg, 10, wd_ln=0.0, lr=5e-2)
        _, p_on, _ = run_steps(cfg, 10, wd_ln=1.0, lr=5e-2)
        assert float(jnp.mean(p_on[gi])) < float(jnp.mean(p_off[gi]))

    def test_act_reg_shrinks_ffn_out(self):
        cfg = micro_config(n_layers=1, name="ar")
        _, p_off, _ = run_steps(cfg, 15, act_reg=0.0)
        _, p_on, _ = run_steps(cfg, 15, act_reg=1.0)
        rec_off, rec_on = M.RecordTap(), M.RecordTap()
        x = batch_for(cfg)[0]
        M.forward(cfg, M.params_to_dict(cfg, p_off), x, 0.0, 1.0, 1.0, tap=rec_off)
        M.forward(cfg, M.params_to_dict(cfg, p_on), x, 0.0, 1.0, 1.0, tap=rec_on)
        off = float(jnp.mean(jnp.square(rec_off.records["L0.ffn_out"])))
        on = float(jnp.mean(jnp.square(rec_on.records["L0.ffn_out"])))
        assert on < off

    def test_grad_clip_keeps_update_bounded(self):
        # Huge lr with clip must not produce NaNs within a few steps.
        cfg = micro_config(name="clip")
        losses, _, _ = run_steps(cfg, 5, lr=0.5)
        assert all(np.isfinite(losses))


class TestEvalStep:
    def test_counts_match_mask(self):
        cfg = micro_config()
        fn, _, _ = T.build_eval_step(cfg)
        params, _, _ = build_state(cfg)
        batch = batch_for(cfg)
        out = jax.jit(fn, keep_unused=True)(
            *params, *batch, jnp.float32(0), jnp.float32(1), jnp.float32(1)
        )
        sum_nll, count, correct = map(float, out)
        assert count == float(jnp.sum(batch[2]))
        assert 0 <= correct <= count
        # untrained model ≈ uniform: nll/token ≈ ln(V)
        assert abs(sum_nll / count - np.log(cfg.vocab_size)) < 1.0

    def test_vit_counts_are_batch(self):
        cfg = micro_vit()
        fn, _, _ = T.build_eval_step(cfg)
        params, _, _ = build_state(cfg)
        batch = batch_for(cfg)
        out = jax.jit(fn, keep_unused=True)(
            *params, *batch, jnp.float32(0), jnp.float32(1), jnp.float32(1)
        )
        assert float(out[1]) == cfg.batch_size


class TestActCollect:
    def test_outputs_match_descs(self):
        cfg = micro_config(n_layers=1, name="ac")
        fn, inputs, outputs = T.build_act_collect(cfg)
        params, _, _ = build_state(cfg)
        batch = batch_for(cfg)
        outs = jax.jit(fn, keep_unused=True)(
            *params, *batch, jnp.float32(0), jnp.float32(1), jnp.float32(1)
        )
        assert len(outs) == len(outputs)
        for o, d in zip(outs, outputs):
            assert tuple(o.shape) == d.shape, d.name
        names = [d.name for d in outputs]
        assert "act::L0.probs" in names
        assert "act::L0.block_out" in names

    def test_quant_points_subset_of_collected(self):
        cfg = micro_config(n_layers=2, name="ac2")
        _, _, outputs = T.build_act_collect(cfg)
        collected = {d.name.removeprefix("act::") for d in outputs if d.name.startswith("act::")}
        for p in M.quant_point_names(cfg):
            assert p in collected, p


class TestEvalQuant:
    def test_generous_ranges_preserve_loss(self):
        cfg = micro_config(n_layers=1, name="eq")
        fn, inputs, outputs, points = T.build_eval_quant(cfg)
        efn, _, _ = T.build_eval_step(cfg)
        params, _, _ = build_state(cfg)
        batch = batch_for(cfg)
        n = len(points)
        scales = jnp.full((n,), 0.02)
        zps = jnp.full((n,), 128.0)
        q = jax.jit(fn, keep_unused=True)(
            *params, scales, zps, jnp.float32(255.0), *batch,
            jnp.float32(0), jnp.float32(1), jnp.float32(1)
        )
        f = jax.jit(efn, keep_unused=True)(
            *params, *batch, jnp.float32(0), jnp.float32(1), jnp.float32(1)
        )
        assert abs(float(q[0]) / float(f[0]) - 1.0) < 0.2

    def test_crushing_ranges_destroy_loss(self):
        """A sanity direction check: absurd scales must hurt (mirrors what
        outliers do to real min-max ranges). The model is briefly trained
        first — on a random-init model both losses sit at max entropy and
        the comparison is a coin flip (this was a flaky seed test)."""
        cfg = micro_config(n_layers=1, name="eq2")
        fn, _, _, points = T.build_eval_quant(cfg)
        _, params, _ = run_steps(cfg, 20)
        batch = batch_for(cfg)
        n = len(points)
        good = jax.jit(fn, keep_unused=True)(
            *params, jnp.full((n,), 0.05), jnp.full((n,), 128.0), jnp.float32(255.0),
            *batch, jnp.float32(0), jnp.float32(1), jnp.float32(1)
        )
        # Grid step 5.0 collapses every unit-scale activation to zero: the
        # trained signal is destroyed and the loss reverts toward uniform.
        bad = jax.jit(fn, keep_unused=True)(
            *params, jnp.full((n,), 5.0), jnp.full((n,), 128.0), jnp.float32(255.0),
            *batch, jnp.float32(0), jnp.float32(1), jnp.float32(1)
        )
        assert float(bad[0]) > float(good[0])


class TestServeScore:
    def test_rows_sum_to_eval_quant_totals(self):
        cfg = micro_config(n_layers=1, name="ss")
        sfn, s_in, s_out = T.build_serve_score(cfg)
        qfn, _, _, points = T.build_eval_quant(cfg)
        params, _, _ = build_state(cfg)
        batch = batch_for(cfg)
        n = len(points)
        args = (
            list(params)
            + [jnp.full((n,), 0.05), jnp.full((n,), 128.0), jnp.float32(255.0)]
            + batch
            + [jnp.float32(0), jnp.float32(1), jnp.float32(1)]
        )
        rows = jax.jit(sfn, keep_unused=True)(*args)
        totals = jax.jit(qfn, keep_unused=True)(*args)
        assert [d.name for d in s_out] == ["nll", "count", "correct"]
        for r, d in zip(rows, s_out):
            assert tuple(r.shape) == (cfg.batch_size,), d.name
        for r, t in zip(rows, totals):
            np.testing.assert_allclose(float(jnp.sum(r)), float(t), rtol=1e-4)

    def test_padding_rows_score_zero(self):
        """An all-zero mask row (how `qtx serve` pads partial batches) must
        contribute nothing — its result is discarded, but NaN/Inf would still
        poison monitoring."""
        cfg = micro_config(n_layers=1, name="ssp")
        sfn, _, _ = T.build_serve_score(cfg)
        params, _, _ = build_state(cfg)
        toks, targets, mask = batch_for(cfg)
        mask = mask.at[-1].set(0.0)
        n = len(M.quant_point_names(cfg))
        rows = jax.jit(sfn, keep_unused=True)(
            *params, jnp.full((n,), 0.05), jnp.full((n,), 128.0), jnp.float32(255.0),
            toks, targets, mask, jnp.float32(0), jnp.float32(1), jnp.float32(1)
        )
        assert float(rows[0][-1]) == 0.0
        assert float(rows[1][-1]) == 0.0
        assert float(rows[2][-1]) == 0.0
        assert all(np.isfinite(np.asarray(r)).all() for r in rows)

    def test_vit_rows_are_per_image(self):
        cfg = micro_vit()
        sfn, _, s_out = T.build_serve_score(cfg)
        params, _, _ = build_state(cfg)
        batch = batch_for(cfg)
        n = len(M.quant_point_names(cfg))
        rows = jax.jit(sfn, keep_unused=True)(
            *params, jnp.full((n,), 0.05), jnp.full((n,), 128.0), jnp.float32(255.0),
            *batch, jnp.float32(0), jnp.float32(1), jnp.float32(1)
        )
        assert tuple(rows[0].shape) == (cfg.batch_size,)
        np.testing.assert_allclose(np.asarray(rows[1]), np.ones(cfg.batch_size))
