"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the whole stack: the AOT HLO the
rust runtime executes is lowered from exactly these traced ops. Hypothesis
sweeps shapes, dtypes and the clipped-softmax stretch factors, including the
exact-zero / clipped-gradient regimes the paper's method depends on.
"""

import pytest

# The offline image does not ship hypothesis; skip the module (instead of
# erroring the whole collection) when it is absent.
hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import attention as A
from compile.kernels import fake_quant as FQ
from compile.kernels import layernorm as LN
from compile.kernels import ref

hypothesis.settings.register_profile(
    "ci", max_examples=25, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("ci")


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


shape_strategy = st.tuples(
    st.integers(1, 3),   # batch
    st.integers(1, 4),   # heads
    st.sampled_from([2, 5, 8]),  # seq
    st.sampled_from([4, 8, 16]),  # d_head
)


class TestAttentionForward:
    @hypothesis.given(
        dims=shape_strategy,
        gamma=st.sampled_from([0.0, -0.003, -0.03, -0.2]),
        zeta=st.sampled_from([1.0, 1.003, 1.03]),
        causal=st.booleans(),
        gated=st.booleans(),
    )
    def test_matches_ref(self, dims, gamma, zeta, causal, gated):
        b, h, t, d = dims
        q, k, v = (rand(i, (b, h, t, d)) for i in range(3))
        g = rand(3, (b, h, t, 1)) if gated else None
        out = A.attention(q, k, v, gamma, zeta, gate_logits=g, causal=causal)
        expect = ref.attention_ref(q, k, v, g, gamma, zeta, causal=causal)
        np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)

    @hypothesis.given(dims=shape_strategy, gamma=st.sampled_from([0.0, -0.05]))
    def test_probs_match_ref(self, dims, gamma):
        b, h, t, d = dims
        q, k = rand(0, (b, h, t, d)), rand(1, (b, h, t, d))
        p = A.attention_probs(q, k, gamma, 1.0)
        expect = ref.attention_probs_ref(q, k, gamma, 1.0)
        np.testing.assert_allclose(p, expect, rtol=1e-5, atol=1e-5)

    def test_vanilla_rows_sum_to_one(self):
        q, k = rand(0, (2, 2, 8, 8)), rand(1, (2, 2, 8, 8))
        p = A.attention_probs(q, k, 0.0, 1.0)
        np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-5)

    def test_clipped_softmax_reaches_exact_zero(self):
        # gamma < 0 must produce exact zeros at *moderate* score ranges —
        # the paper's core mechanism: zeros with finite dynamic range. At
        # the same range the vanilla softmax stays strictly positive (it
        # only reaches 0 by f32 underflow at ~90+ logit gaps).
        q = 1.5 * rand(0, (1, 1, 8, 8))
        k = 1.5 * rand(1, (1, 1, 8, 8))
        p = A.attention_probs(q, k, -0.03, 1.0)
        assert (np.asarray(p) == 0.0).any(), "no exact zeros with gamma<0"
        p0 = np.asarray(A.attention_probs(q, k, 0.0, 1.0))
        assert (p0 > 0.0).all(), f"vanilla underflowed: min {p0.min()}"

    def test_causal_masks_future(self):
        q, k = rand(0, (1, 1, 6, 4)), rand(1, (1, 1, 6, 4))
        p = np.asarray(A.attention_probs(q, k, 0.0, 1.0, causal=True))
        upper = np.triu(np.ones((6, 6), bool), 1)
        assert (p[0, 0][upper] == 0).all()

    def test_gamma_zero_equals_vanilla(self):
        q, k, v = (rand(i, (2, 2, 6, 8)) for i in range(3))
        a = A.attention(q, k, v, 0.0, 1.0)
        b = ref.attention_ref(q, k, v, None, 0.0, 1.0)
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


class TestAttentionBackward:
    @hypothesis.given(
        dims=shape_strategy,
        gamma=st.sampled_from([0.0, -0.03]),
        causal=st.booleans(),
        gated=st.booleans(),
    )
    def test_grads_match_ref(self, dims, gamma, causal, gated):
        b, h, t, d = dims
        q, k, v = (rand(i, (b, h, t, d)) for i in range(3))
        g = rand(3, (b, h, t, 1)) if gated else jnp.zeros((b, h, t, 1))
        use_g = g if gated else None

        def loss_k(q, k, v, g):
            gl = g if gated else None
            return jnp.sum(jnp.sin(A.attention(q, k, v, gamma, 1.0, gate_logits=gl, causal=causal)))

        def loss_r(q, k, v, g):
            gl = g if gated else None
            return jnp.sum(jnp.sin(ref.attention_ref(q, k, v, gl, gamma, 1.0, causal=causal)))

        gk = jax.grad(loss_k, argnums=(0, 1, 2, 3))(q, k, v, g)
        gr = jax.grad(loss_r, argnums=(0, 1, 2, 3))(q, k, v, g)
        for a, b_, name in zip(gk, gr, "qkvg"):
            np.testing.assert_allclose(
                a, b_, rtol=1e-4, atol=1e-5, err_msg=f"grad {name}"
            )
        _ = use_g

    def test_clipped_entries_stop_gradient(self):
        # Section 4.1: entries clipped to 0 give exactly zero gradient —
        # the property that stops outlier growth. Diagonal-dominant scores
        # chosen so p0_diag ≈ 0.95 (NOT f32-saturated: the vanilla softmax
        # still has gradients everywhere) while every off-diagonal falls
        # below the gamma=-0.03 clip threshold gamma/(zeta-gamma) ≈ 0.029.
        t = 6
        a = float(np.log(5.0 * 0.95 / 0.05))  # p0_diag = 0.95 for 6 keys
        scale = a * np.sqrt(8.0)
        q = np.sqrt(scale) * jnp.eye(t, 8)[None, None]
        k = np.sqrt(scale) * jnp.eye(t, 8)[None, None]

        p_clip = np.asarray(A.attention_probs(q, k, -0.03, 1.0))[0, 0]
        off = ~np.eye(t, dtype=bool)
        assert (p_clip[off] == 0.0).all(), "off-diagonals not clipped"
        assert (p_clip.diagonal() < 1.0).all(), "diag should stay interior"

        # v = one-hot rows so attention output == probability matrix (the
        # probs-only kernel is forward-only; the fused op has the VJP).
        v = jnp.eye(t, 8)[None, None]

        def off_mass(kk, gamma):
            p = A.attention(q, kk, v, gamma, 1.0)[0, 0, :, :t]
            return jnp.sum(p * off)

        g_clipped = jax.grad(off_mass)(k, -0.03)
        g_vanilla = jax.grad(off_mass)(k, 0.0)
        # clipped-to-zero outputs are constants: zero gradient to k
        assert float(jnp.abs(g_clipped).max()) == 0.0
        assert float(jnp.abs(g_vanilla).max()) > 1e-7


class TestFakeQuant:
    @hypothesis.given(
        n=st.integers(1, 64),
        m=st.integers(1, 16),
        bits=st.sampled_from([4, 6, 8]),
        scale=st.sampled_from([0.01, 0.05, 0.3]),
        zp=st.sampled_from([0.0, 7.0, 128.0]),
    )
    def test_matches_ref(self, n, m, bits, scale, zp):
        x = 3.0 * rand(0, (n, m))
        qmax = float(2**bits - 1)
        out = FQ.fake_quant(x, scale, zp, qmax)
        expect = ref.fake_quant_ref(x, scale, zp, qmax)
        np.testing.assert_allclose(out, expect, rtol=1e-6, atol=1e-6)

    def test_output_is_on_grid(self):
        x = rand(0, (16, 16))
        s, z, qmax = 0.05, 12.0, 255.0
        y = np.asarray(FQ.fake_quant(x, s, z, qmax))
        q = y / s + z
        np.testing.assert_allclose(q, np.round(q), atol=1e-4)
        assert q.min() >= -1e-4 and q.max() <= qmax + 1e-4

    def test_rank_handling(self):
        for shape in [(5,), (3, 4), (2, 3, 4), (2, 2, 2, 2)]:
            x = rand(1, shape)
            y = FQ.fake_quant(x, 0.1, 8.0, 255.0)
            assert y.shape == x.shape

    def test_straight_through_gradient(self):
        x = rand(0, (4, 4))
        g = jax.grad(lambda x: jnp.sum(FQ.fake_quant(x, 0.1, 8.0, 255.0)))(x)
        np.testing.assert_allclose(g, jnp.ones_like(x), atol=1e-6)


class TestLayerNorm:
    @hypothesis.given(
        rows=st.integers(1, 16),
        d=st.sampled_from([4, 16, 32]),
        lead=st.booleans(),
    )
    def test_matches_ref(self, rows, d, lead):
        shape = (2, rows, d) if lead else (rows, d)
        x = 2.0 * rand(0, shape)
        g = rand(1, (d,)) + 1.0
        b = rand(2, (d,))
        np.testing.assert_allclose(
            LN.layernorm(x, g, b), ref.layernorm_ref(x, g, b), rtol=1e-5, atol=1e-5
        )

    def test_grads_match_ref(self):
        x, g, b = 2.0 * rand(0, (6, 16)), rand(1, (16,)) + 1.0, rand(2, (16,))

        def lk(x, g, b):
            return jnp.sum(jnp.cos(LN.layernorm(x, g, b)))

        def lr(x, g, b):
            return jnp.sum(jnp.cos(ref.layernorm_ref(x, g, b)))

        gk = jax.grad(lk, argnums=(0, 1, 2))(x, g, b)
        gr = jax.grad(lr, argnums=(0, 1, 2))(x, g, b)
        for a, e, n in zip(gk, gr, ["x", "gamma", "beta"]):
            np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-5, err_msg=n)

    def test_normalizes_huge_outliers(self):
        # The paper's mechanism needs LN to normalize outliers (Fig 4): a
        # 1000x outlier row still comes out with bounded values.
        x = jnp.ones((2, 32)).at[0, 0].set(1000.0)
        y = LN.layernorm(x, jnp.ones(32), jnp.zeros(32))
        assert float(jnp.abs(y).max()) < 10.0


@pytest.mark.parametrize("gamma,zeta", [(0.0, 1.0), (-0.03, 1.0), (-0.1, 1.05)])
def test_clipped_softmax_bounds(gamma, zeta):
    x = 10.0 * rand(0, (4, 16))
    p = np.asarray(ref.clipped_softmax(x, gamma, zeta))
    assert p.min() >= 0.0 and p.max() <= 1.0
