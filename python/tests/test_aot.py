"""AOT emission tests: HLO text + manifest integrity for a micro config
(the contract consumed by rust/src/runtime/artifact.rs)."""

import json

import pytest

from compile import aot
from compile.train import PROGRAM_BUILDERS
from tests.conftest import micro_config


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    cfg = micro_config(name="micro_aot")
    did = aot.lower_config(cfg, out)
    assert did
    return cfg, out / "micro_aot"


def test_manifest_carries_format_version(built):
    """The rust side reports found-vs-required versions in its serve-path
    errors; the manifest must therefore carry an explicit version."""
    _, cdir = built
    manifest = json.loads((cdir / "manifest.json").read_text())
    assert manifest["version"] == aot.MANIFEST_VERSION
    assert manifest["version"] >= 5  # v5 introduced serve_score


def test_all_programs_emitted(built):
    _, cdir = built
    manifest = json.loads((cdir / "manifest.json").read_text())
    assert set(manifest["programs"]) == set(PROGRAM_BUILDERS)
    for prog in manifest["programs"].values():
        hlo = (cdir / prog["file"]).read_text()
        assert hlo.startswith("HloModule"), prog["file"]
        assert "ENTRY" in hlo


def test_manifest_io_counts(built):
    cfg, cdir = built
    manifest = json.loads((cdir / "manifest.json").read_text())
    n = len(manifest["params"])
    ts = manifest["programs"]["train_step"]
    # params + m + v + step + (x,targets,mask) + 6 hyper scalars
    assert len(ts["inputs"]) == 3 * n + 1 + 3 + 6
    assert len(ts["outputs"]) == 3 * n + 2
    eq = manifest["programs"]["eval_quant"]
    npts = len(manifest["quant_points"])
    scale_in = next(d for d in eq["inputs"] if d["name"] == "act_scale")
    assert scale_in["shape"] == [npts]


def test_hlo_parameter_count_matches_manifest(built):
    """keep_unused must hold: the HLO entry takes exactly the manifest's
    inputs (this is the bug class that broke the first smoke run)."""
    _, cdir = built
    manifest = json.loads((cdir / "manifest.json").read_text())
    for name, prog in manifest["programs"].items():
        hlo = (cdir / prog["file"]).read_text()
        entry = hlo[hlo.index("\nENTRY") :]
        count = sum(
            " parameter(" in line
            for line in entry.splitlines()
        )
        assert count == len(prog["inputs"]), (
            f"{name}: HLO entry has {count} params, manifest {len(prog['inputs'])}"
        )


def test_package_block_covers_every_payload_file(built):
    """Manifest v2: the "package" block must checksum every payload file
    (manifest excluded) and carry the provenance record the serving side
    surfaces in /statz (see rust/src/runtime/package.rs)."""
    import hashlib

    cfg, cdir = built
    manifest = json.loads((cdir / "manifest.json").read_text())
    pkg = manifest["package"]
    assert pkg["schema"] == aot.PACKAGE_SCHEMA
    assert len(pkg["install_id"]) == 16

    payload = sorted(
        p.name for p in cdir.iterdir()
        if p.is_file() and p.name != "manifest.json" and not p.name.startswith(".")
    )
    assert [e["path"] for e in pkg["entries"]] == payload
    for entry in pkg["entries"]:
        data = (cdir / entry["path"]).read_bytes()
        assert entry["bytes"] == len(data)
        assert entry["sha256"] == hashlib.sha256(data).hexdigest()
        assert entry["kind"] == "program"  # aot emits only .hlo.txt payloads

    prov = pkg["provenance"]
    assert prov["config"] == cfg.name
    assert prov["fingerprint"] == manifest["fingerprint"]
    assert prov["variant"].startswith(cfg.attention)
    assert len(prov["calibration_id"]) == 16
    assert prov["toolchain"].startswith("aot.py")


def test_fingerprint_skips_rebuild(built, tmp_path):
    cfg, _ = built
    assert aot.lower_config(cfg, tmp_path) is True
    assert aot.lower_config(cfg, tmp_path) is False  # up to date
    assert aot.lower_config(cfg, tmp_path, force=True) is True


def test_fingerprint_changes_with_config(built):
    cfg, _ = built
    import dataclasses
    cfg2 = dataclasses.replace(cfg, n_layers=cfg.n_layers + 1)
    assert aot.config_fingerprint(cfg) != aot.config_fingerprint(cfg2)
