"""Shared fixtures: micro model configs that trace in milliseconds."""

import dataclasses

import pytest

from compile.configs import ModelConfig


def micro_config(**overrides) -> ModelConfig:
    base = dict(
        name="micro",
        family="bert",
        attention="softmax",
        n_layers=2,
        d_model=16,
        n_heads=2,
        d_ff=32,
        seq_len=8,
        vocab_size=32,
        ln_placement="post",
        causal=False,
        objective="mlm",
        batch_size=2,
        gate_hidden=3,
    )
    base.update(overrides)
    cfg = ModelConfig(**base)
    cfg.validate()
    return cfg


def micro_opt(**overrides) -> ModelConfig:
    return micro_config(
        name="micro_opt", family="opt", ln_placement="pre", causal=True,
        objective="clm", adam_b2=0.95, init_std=0.006, **overrides,
    )


def micro_vit(**overrides) -> ModelConfig:
    return micro_config(
        name="micro_vit", family="vit", ln_placement="pre", objective="cls",
        vocab_size=0, n_classes=4, patch_dim=12, seq_len=5, **overrides,
    )


@pytest.fixture
def bert_cfg():
    return micro_config()


@pytest.fixture
def opt_cfg():
    return micro_opt()


@pytest.fixture
def vit_cfg():
    return micro_vit()


def all_attention_variants(cfg_fn):
    return [
        cfg_fn(attention=a, name=f"{cfg_fn.__name__}_{a}")
        for a in ("softmax", "gated_linear", "gated_mlp", "gated_allheads")
    ]


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)
