"""L2 model tests: parameter inventory, forward shapes, attention-variant
equivalences, tap/quant-point machinery."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import REGISTRY
from tests.conftest import micro_config, micro_opt, micro_vit

VARIANTS = ("softmax", "gated_linear", "gated_mlp", "gated_allheads")


def make_params(cfg, seed=0, b_init=0.0):
    flat = M.init_params(cfg, seed, b_init)
    return M.params_to_dict(cfg, flat), flat


def example_batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.family == "vit":
        return jnp.asarray(
            rng.normal(size=(cfg.batch_size, cfg.seq_len - 1, cfg.patch_dim)),
            jnp.float32,
        )
    return jnp.asarray(
        rng.integers(6, cfg.vocab_size, (cfg.batch_size, cfg.seq_len)), jnp.int32
    )


class TestParams:
    @pytest.mark.parametrize("att", VARIANTS)
    def test_specs_match_init(self, att):
        cfg = micro_config(attention=att, name=f"m_{att}")
        specs = M.param_specs(cfg)
        flat = M.init_params(cfg, 0, 0.5)
        assert len(specs) == len(flat)
        for s, a in zip(specs, flat):
            assert tuple(a.shape) == s.shape, s.name

    def test_gate_bias_init_value(self):
        cfg = micro_config(attention="gated_linear", name="g")
        p, _ = make_params(cfg, b_init=-1.5)
        np.testing.assert_allclose(p["L0.gate.b"], -1.5)

    def test_head_not_quantized_everything_2d_is(self):
        cfg = micro_config()
        for s in M.param_specs(cfg):
            if s.name.startswith("head."):
                assert not s.quantize, s.name
            if s.name.endswith((".wq", ".wk", ".wv", ".wo", ".w1", ".w2")):
                assert s.quantize, s.name

    def test_ln_gamma_flags(self):
        cfg = micro_opt()
        flags = {s.name: s.ln_gamma for s in M.param_specs(cfg)}
        assert flags["L0.ln1.g"] and flags["final_ln.g"]
        assert not flags["L0.ln1.b"] and not flags["L0.wq"]

    def test_seeds_change_init(self):
        cfg = micro_config()
        a = M.init_params(cfg, 0, 0.0)
        b = M.init_params(cfg, 1, 0.0)
        diffs = [
            float(jnp.abs(x - y).max())
            for x, y, s in zip(a, b, M.param_specs(cfg))
            if s.init == "normal"
        ]
        assert all(d > 0 for d in diffs)


class TestForward:
    @pytest.mark.parametrize("att", VARIANTS)
    def test_bert_logits_shape(self, att):
        cfg = micro_config(attention=att, name=f"f_{att}")
        p, _ = make_params(cfg)
        logits = M.forward(cfg, p, example_batch(cfg), 0.0, 1.0, 1.0)
        assert logits.shape == (cfg.batch_size, cfg.seq_len, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())

    def test_opt_and_vit_shapes(self):
        cfg = micro_opt()
        p, _ = make_params(cfg)
        logits = M.forward(cfg, p, example_batch(cfg), 0.0, 1.0, 1.0)
        assert logits.shape == (cfg.batch_size, cfg.seq_len, cfg.vocab_size)

        cfg = micro_vit()
        p, _ = make_params(cfg)
        logits = M.forward(cfg, p, example_batch(cfg), 0.0, 1.0, 1.0)
        assert logits.shape == (cfg.batch_size, cfg.n_classes)

    @pytest.mark.parametrize("att", VARIANTS)
    def test_fused_equals_decomposed(self, att):
        """eval/train use the fused Pallas attention; act_collect/eval_quant
        use the decomposed path — they must agree exactly."""
        cfg = micro_config(attention=att, name=f"d_{att}")
        p, _ = make_params(cfg, b_init=0.3)
        x = example_batch(cfg)
        a = M.forward(cfg, p, x, -0.02, 1.0, 1.0)
        b = M.forward(cfg, p, x, -0.02, 1.0, 1.0, decompose_attention=True)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    def test_causal_no_leak(self):
        """Changing future tokens must not change past logits (OPT)."""
        cfg = micro_opt()
        p, _ = make_params(cfg)
        x = example_batch(cfg)
        y1 = M.forward(cfg, p, x, 0.0, 1.0, 1.0)
        x2 = x.at[:, -1].set((x[:, -1] + 1) % cfg.vocab_size)
        y2 = M.forward(cfg, p, x2, 0.0, 1.0, 1.0)
        np.testing.assert_allclose(y1[:, :-1], y2[:, :-1], rtol=1e-5, atol=1e-6)
        assert float(jnp.abs(y1[:, -1] - y2[:, -1]).max()) > 1e-6

    def test_gate_scale_scales_update(self):
        """With gates, doubling gate_scale doubles the attention update
        (before the residual) — §B.6's x2 trick."""
        cfg = micro_config(attention="gated_linear", name="gs", n_layers=1)
        p, _ = make_params(cfg)
        x = example_batch(cfg)
        rec1, rec2 = M.RecordTap(), M.RecordTap()
        M.forward(cfg, p, x, 0.0, 1.0, 1.0, tap=rec1)
        M.forward(cfg, p, x, 0.0, 1.0, 2.0, tap=rec2)
        np.testing.assert_allclose(
            2.0 * rec1.records["L0.ctx"], rec2.records["L0.ctx"], rtol=1e-5
        )

    def test_closed_gates_freeze_residual(self):
        """b_init -> -inf approximates a hard no-op: the attention update
        vanishes and block output ≈ FFN-only path."""
        cfg = micro_config(attention="gated_linear", name="closed", n_layers=1)
        p, _ = make_params(cfg, b_init=-30.0)
        x = example_batch(cfg)
        rec = M.RecordTap()
        M.forward(cfg, p, x, 0.0, 1.0, 1.0, tap=rec)
        assert float(jnp.abs(rec.records["L0.ctx"]).max()) < 1e-8
        np.testing.assert_allclose(
            rec.records["L0.res1"], rec.records["embed"], rtol=1e-6
        )


class TestQuantPoints:
    def test_points_are_stable_and_complete(self):
        cfg = micro_config()
        pts = M.quant_point_names(cfg)
        assert pts == M.quant_point_names(cfg)  # deterministic
        assert "embed" in pts
        for l in range(cfg.n_layers):
            for suffix in ("q", "k", "v", "probs", "ctx", "attn_out", "res1",
                           "ffn_h", "ffn_out", "res2"):
                assert f"L{l}.{suffix}" in pts, f"L{l}.{suffix}"
        # analysis-only taps excluded
        assert not any(p.endswith((".values", ".gate_probs", ".block_out")) for p in pts)

    def test_quant_tap_quantizes_only_known_points(self):
        cfg = micro_config(name="qt", n_layers=1)
        pts = M.quant_point_names(cfg)
        idx = {n: i for i, n in enumerate(pts)}
        scales = jnp.full((len(pts),), 0.05)
        zps = jnp.full((len(pts),), 128.0)
        tap = M.QuantTap(idx, scales, zps, jnp.float32(255.0))
        x = jnp.linspace(-1, 1, 12).reshape(3, 4)
        y = tap("embed", x)
        assert float(jnp.abs(y - x).max()) > 0  # quantized
        z = tap("not_a_point", x)
        np.testing.assert_array_equal(z, x)  # passthrough

    def test_quantized_forward_close_at_8bit(self):
        cfg = micro_config(name="q8", n_layers=1)
        p, _ = make_params(cfg)
        x = example_batch(cfg)
        rec = M.RecordTap()
        clean = M.forward(cfg, p, x, 0.0, 1.0, 1.0, tap=rec, decompose_attention=True)
        pts = M.quant_point_names(cfg)
        idx = {n: i for i, n in enumerate(pts)}
        # generous per-point ranges from the recorded activations
        scales, zps = [], []
        for n in pts:
            t = rec.records[n]
            lo = float(jnp.min(t).clip(max=0.0))
            hi = float(jnp.max(t).clip(min=0.0))
            s = max(hi - lo, 1e-6) / 255.0
            scales.append(s)
            zps.append(round(-lo / s))
        tap = M.QuantTap(idx, jnp.asarray(scales), jnp.asarray(zps), jnp.float32(255.0))
        qout = M.forward(cfg, p, x, 0.0, 1.0, 1.0, tap=tap, decompose_attention=True)
        # logits shift but stay close at 8 bits on a tiny clean model
        assert float(jnp.abs(qout - clean).max()) < 0.5


class TestRegistry:
    def test_registry_configs_validate(self):
        for name, cfg in REGISTRY.items():
            cfg.validate()
            assert cfg.name == name

    def test_registry_has_all_families_and_variants(self):
        fams = {c.family for c in REGISTRY.values()}
        assert fams == {"bert", "opt", "vit"}
        atts = {c.attention for c in REGISTRY.values()}
        assert atts == set(VARIANTS)

    def test_param_specs_unique_names(self):
        for cfg in list(REGISTRY.values())[:4]:
            names = [s.name for s in M.param_specs(cfg)]
            assert len(names) == len(set(names))

    def test_fig6_seqlen_sweep_exists(self):
        for t in (16, 32, 64):
            cfg = REGISTRY[f"bert6l_t{t}_softmax"]
            assert cfg.seq_len == t and cfg.n_layers == 6

    def test_patchln_variants_differ(self):
        a = REGISTRY["vit_tiny_softmax"]
        b = REGISTRY["vit_tiny_softmax_patchln"]
        assert not a.patch_ln and b.patch_ln
        assert dataclasses.replace(a, name="x", patch_ln=True) == dataclasses.replace(
            b, name="x"
        )
